//! The serving pipeline: a nonblocking I/O thread feeding per-model
//! execution lanes, with admission control and streamed replies.
//!
//! ```text
//! listener -> I/O thread -------- lanes (kernels::pool::spawn_service)
//!             accept              +--------------------------------+
//!             poll_recv (buffered | lane 0: mlp128   Batcher ->    |
//!               per-conn frames)  |   chunked forward -> LaneOut   |
//!             validate            | lane 1: vgg8bn   Batcher ->    |
//!             admission control   |   chunked forward -> LaneOut   |
//!             dispatch --------->-+--------------------------------+
//!             send replies <------------ LaneOut stream
//! ```
//!
//! The I/O thread owns every socket: it accepts, reassembles frames
//! incrementally per connection ([`super::conn::ServeConn`] — a
//! half-read frame costs other connections nothing), validates
//! requests against the registry, and applies **admission control**:
//! if the target lane already holds `max_queue` requests the server
//! answers a typed [`Msg::Busy`] with a retry hint instead of queueing
//! unboundedly — memory stays bounded under overload and the client
//! gets an actionable backoff. Admitted requests go to their model's
//! lane, which micro-batches and executes them and streams each
//! chunk's replies back while later chunks still compute.
//!
//! A malformed or invalid request still earns a faulted `Shutdown`
//! naming the reason and drops only that connection — the server
//! itself never exits on peer misbehavior.

use super::batcher::Pending;
use super::conn::ServeConn;
use super::lanes::{LaneOut, LanePool};
use super::QuantMode;
use crate::net::Msg;
use crate::runtime::Engine;
use crate::util::math::percentile;
use anyhow::{bail, Context, Result};
use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, TryRecvError};
use std::time::{Duration, Instant};

/// Hard cap on examples per request, mirroring the decoder's guard in
/// `net::proto` so an admitted request can never out-size the wire.
pub const MAX_REQUEST_BATCH: usize = 4096;

/// Env var overriding the default execution-lane count.
pub const ENV_LANES: &str = "DITHERPROP_SERVE_LANES";

/// Default lane count: `DITHERPROP_SERVE_LANES` when set, else 2 (one
/// fast and one slow model run side by side without head-of-line
/// blocking; more models than lanes share round-robin).
pub fn default_lanes() -> usize {
    std::env::var(ENV_LANES)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|v| v.clamp(1, 64))
        .unwrap_or(2)
}

#[derive(Debug, Clone)]
pub struct ServeCfg {
    pub quant: QuantMode,
    /// Seed + steps of the deterministic weight reconstruction
    /// ([`crate::train::serving_params`]) — clients that want to
    /// `--check` replies must use the same pair.
    pub seed: u64,
    pub steps: usize,
    /// Flush a lane's micro-batch queue at this many queued examples;
    /// also the chunk size of streamed execution (one forward covers
    /// at most this many examples).
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long.
    pub max_delay: Duration,
    /// LRU capacity of each lane's prepared-plan cache.
    pub cache_cap: usize,
    /// Serve exactly this many requests, then return (tests, benches,
    /// CI smoke). `None` serves until the process dies.
    pub max_requests: Option<u64>,
    /// Execution lanes (persistent forward workers). Min 1.
    pub lanes: usize,
    /// Admission cap: a request whose lane already holds this many
    /// requests is answered `Busy` instead of queued.
    pub max_queue: usize,
    /// Models served BN-folded fp32 regardless of `quant` (mixed-mode
    /// serving: e.g. int8 mlp128 next to fp32 vgg8bn in one process).
    pub fp32_models: Vec<String>,
    pub verbose: bool,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            quant: QuantMode::Int8,
            seed: 42,
            steps: 40,
            max_batch: 32,
            max_delay: Duration::from_millis(2),
            cache_cap: 4,
            max_requests: None,
            lanes: default_lanes(),
            max_queue: 64,
            fp32_models: Vec::new(),
            verbose: false,
        }
    }
}

impl ServeCfg {
    /// Numeric mode for `model`: the global `quant` unless the model
    /// is listed in `fp32_models`.
    pub fn quant_for(&self, model: &str) -> QuantMode {
        if self.fp32_models.iter().any(|m| m == model) {
            QuantMode::Fp32
        } else {
            self.quant
        }
    }
}

/// Counters and latency samples from one `run_serve` call.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Requests answered with an `InferReply`.
    pub served: u64,
    /// Examples inside those requests.
    pub examples: u64,
    /// Forward passes (flushed chunks, across all lanes).
    pub batches: u64,
    /// Requests rejected with a faulted `Shutdown` (or whose reply had
    /// no live connection left to receive it).
    pub rejected: u64,
    /// Requests answered `Busy` by admission control (not counted as
    /// served or rejected; clients retry them).
    pub busy: u64,
    /// Admission-to-reply latency of each served request, milliseconds.
    pub latencies_ms: Vec<f64>,
    /// Stage splits of the same requests: admission -> forward start.
    pub queue_ms: Vec<f64>,
    /// Forward start -> forward end (the chunk's execution).
    pub exec_ms: Vec<f64>,
    /// Forward end -> reply on the socket.
    pub reply_ms: Vec<f64>,
    /// Per-lane high-water mark of queue depth.
    pub lane_depth_max: Vec<usize>,
    /// Execution lanes the server ran.
    pub lanes: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub elapsed_s: f64,
}

impl ServeStats {
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 99.0)
    }

    pub fn queue_p99_ms(&self) -> f64 {
        percentile(&self.queue_ms, 99.0)
    }

    pub fn exec_p99_ms(&self) -> f64 {
        percentile(&self.exec_ms, 99.0)
    }

    pub fn reply_p99_ms(&self) -> f64 {
        percentile(&self.reply_ms, 99.0)
    }

    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.served as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "served {} requests ({} examples) in {} forwards over {:.2}s | \
             p50 {:.3} ms, p99 {:.3} ms, {:.1} req/s | \
             stage p99 queue/exec/reply {:.3}/{:.3}/{:.3} ms | \
             {} lanes (max depth {:?}) | \
             cache {} hits / {} misses | {} busy | {} rejected",
            self.served,
            self.examples,
            self.batches,
            self.elapsed_s,
            self.p50_ms(),
            self.p99_ms(),
            self.req_per_s(),
            self.queue_p99_ms(),
            self.exec_p99_ms(),
            self.reply_p99_ms(),
            self.lanes,
            self.lane_depth_max,
            self.cache_hits,
            self.cache_misses,
            self.busy,
            self.rejected
        )
    }
}

/// Validate an admitted `InferRequest` against the model registry.
/// Decode guards already bounded `batch`; this adds existence and
/// exact input-size checks so the forward can never see a shape error.
fn validate(engine: &Engine, model: &str, batch: u32, x_len: usize) -> Result<(), String> {
    let entry = match engine.manifest.models.get(model) {
        Some(e) => e,
        None => return Err(format!("unknown model '{model}'")),
    };
    let numel: usize = entry.input_shape.iter().product();
    if batch == 0 || batch as usize > MAX_REQUEST_BATCH {
        return Err(format!("batch {batch} outside 1..={MAX_REQUEST_BATCH}"));
    }
    if x_len != batch as usize * numel {
        return Err(format!(
            "model '{model}': {x_len} input values, expected {} (batch {batch} x {numel})",
            batch as usize * numel
        ));
    }
    Ok(())
}

/// Send a faulted `Shutdown` naming `reason`, then drop the slot.
fn fault_drop(slot: &mut Option<ServeConn>, reason: &str) {
    if let Some(c) = slot.as_mut() {
        let _ = c.send(&Msg::Shutdown { fault: true, reason: reason.to_string() });
    }
    *slot = None;
}

/// The `Busy` retry hint: one flush delay plus the lane's estimated
/// drain time (depth x mean execution), clamped to a sane range.
fn retry_hint_ms(cfg: &ServeCfg, depth: usize, exec_mean_ms: Option<f64>) -> u32 {
    let mean = exec_mean_ms.unwrap_or_else(|| cfg.max_delay.as_secs_f64() * 1e3);
    let est = cfg.max_delay.as_secs_f64() * 1e3 + mean * depth as f64;
    est.clamp(1.0, 60_000.0) as u32
}

/// Run the serving pipeline on an already-bound listener until
/// `max_requests` is reached (never returns when it is `None`).
pub fn run_serve(listener: &TcpListener, cfg: &ServeCfg) -> Result<ServeStats> {
    listener.set_nonblocking(true).context("setting listener nonblocking")?;
    let engine = Engine::native()?;
    let (out_tx, out_rx) = channel::<LaneOut>();
    let mut pool = LanePool::start(cfg, out_tx);
    let mut conns: Vec<Option<ServeConn>> = Vec::new();
    let mut stats = ServeStats { lanes: pool.lane_count(), ..ServeStats::default() };
    let started = Instant::now();
    // Running mean of chunk execution time, feeding the Busy hint.
    let mut exec_sum_ms = 0.0f64;
    let mut exec_n = 0u64;

    loop {
        let mut progressed = false;

        // Stage 1: admit every connection waiting on the listener.
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => match ServeConn::from_stream(stream) {
                    Ok(c) => {
                        conns.push(Some(c));
                        progressed = true;
                    }
                    Err(e) => {
                        if cfg.verbose {
                            eprintln!("[serve] rejected connection: {e:#}");
                        }
                    }
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("accepting connection"),
            }
        }

        // Stage 2: one nonblocking poll per connection, draining every
        // complete frame it has buffered. A half-read frame stays
        // buffered on its own connection (per-connection deadline
        // inside ServeConn) and costs this sweep nothing.
        let now = Instant::now();
        for ci in 0..conns.len() {
            loop {
                let msg = {
                    let Some(slot) = conns.get_mut(ci) else { break };
                    let Some(c) = slot.as_mut() else { break };
                    match c.poll_recv(now) {
                        Ok(Some(m)) => m,
                        Ok(None) => break,
                        Err(e) => {
                            if cfg.verbose {
                                eprintln!("[serve] dropping connection: {e:#}");
                            }
                            *slot = None;
                            break;
                        }
                    }
                };
                progressed = true;
                match msg {
                    Msg::InferRequest { id, model, batch, x } => {
                        if let Err(reason) = validate(&engine, &model, batch, x.len()) {
                            stats.rejected += 1;
                            if let Some(slot) = conns.get_mut(ci) {
                                fault_drop(slot, &reason);
                            }
                            break;
                        }
                        let lane = pool.lane_for(&model);
                        let depth = pool.depth(lane);
                        if depth >= cfg.max_queue.max(1) {
                            // Admission control: typed Busy, request not
                            // queued, connection stays open.
                            stats.busy += 1;
                            let mean =
                                if exec_n > 0 { Some(exec_sum_ms / exec_n as f64) } else { None };
                            let hint = retry_hint_ms(cfg, depth, mean);
                            let busy = Msg::Busy { id, retry_after_ms: hint };
                            let Some(slot) = conns.get_mut(ci) else { break };
                            let alive =
                                slot.as_mut().map(|c| c.send(&busy).is_ok()).unwrap_or(false);
                            if !alive {
                                *slot = None;
                                break;
                            }
                            continue;
                        }
                        pool.dispatch(
                            lane,
                            Pending {
                                conn: ci,
                                id,
                                model,
                                batch: batch as usize,
                                x,
                                arrived: Instant::now(),
                            },
                        )?;
                    }
                    Msg::Shutdown { .. } => {
                        if let Some(slot) = conns.get_mut(ci) {
                            *slot = None;
                        }
                        break;
                    }
                    other => {
                        stats.rejected += 1;
                        if let Some(slot) = conns.get_mut(ci) {
                            fault_drop(slot, &format!("unexpected message tag {}", other.tag()));
                        }
                        break;
                    }
                }
            }
        }

        // Stage 3: drain lane outputs and put replies on the wire.
        // Chunked lanes emit while later chunks compute, so replies
        // stream out of this drain across sweeps.
        loop {
            match out_rx.try_recv() {
                Ok(o) => {
                    progressed = true;
                    let sent_ok = match conns.get_mut(o.conn) {
                        Some(slot) => match slot.as_mut() {
                            Some(c) => match c.send(&o.reply) {
                                Ok(()) => true,
                                Err(_) => {
                                    *slot = None;
                                    false
                                }
                            },
                            None => false,
                        },
                        None => false,
                    };
                    if o.fault {
                        stats.rejected += 1;
                        if let Some(slot) = conns.get_mut(o.conn) {
                            *slot = None;
                        }
                    } else if sent_ok {
                        let done = Instant::now();
                        let ms = |d: Duration| d.as_secs_f64() * 1e3;
                        stats.served += 1;
                        stats.examples += o.examples;
                        let exec = ms(o.exec_done.saturating_duration_since(o.exec_start));
                        stats.queue_ms.push(ms(o.exec_start.saturating_duration_since(o.arrived)));
                        stats.exec_ms.push(exec);
                        stats.reply_ms.push(ms(done.saturating_duration_since(o.exec_done)));
                        stats.latencies_ms.push(ms(done.saturating_duration_since(o.arrived)));
                        exec_sum_ms += exec;
                        exec_n += 1;
                        if cfg.verbose && stats.served % 64 == 0 {
                            eprintln!(
                                "[serve] {} requests served ({} busy, {} rejected)",
                                stats.served, stats.busy, stats.rejected
                            );
                        }
                    } else {
                        // The reply had no live connection left: still a
                        // terminal outcome, or `max_requests` accounting
                        // could stall the shutdown.
                        stats.rejected += 1;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => bail!("every execution lane died"),
            }
        }

        if let Some(cap) = cfg.max_requests {
            // `all_idle` (Acquire) observes each lane's decrement only
            // after its output send, and outputs were just drained — so
            // idle + cap reached means nothing is in flight anywhere.
            if stats.served + stats.rejected >= cap && pool.all_idle() {
                break;
            }
        }

        if !progressed {
            std::thread::sleep(Duration::from_micros(300));
        }
    }

    pool.shutdown();
    stats.lane_depth_max = pool.depth_maxes();
    let c = pool.counters();
    stats.batches = c.batches.load(Ordering::Relaxed);
    stats.cache_hits = c.cache_hits.load(Ordering::Relaxed);
    stats.cache_misses = c.cache_misses.load(Ordering::Relaxed);
    stats.elapsed_s = started.elapsed().as_secs_f64();
    if cfg.verbose {
        eprintln!("[serve] {}", stats.summary());
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_unknown_model_bad_batch_and_bad_len() {
        let engine = Engine::native().unwrap();
        let entry = engine.manifest.models.get("mlp128").unwrap();
        let numel: usize = entry.input_shape.iter().product();
        assert!(validate(&engine, "mlp128", 2, 2 * numel).is_ok());
        assert!(validate(&engine, "no-such-model", 1, numel).is_err());
        assert!(validate(&engine, "mlp128", 0, 0).is_err());
        assert!(validate(&engine, "mlp128", 5000, 5000 * numel).is_err());
        assert!(validate(&engine, "mlp128", 2, 2 * numel + 1).is_err());
    }

    #[test]
    fn stats_summary_reports_percentiles_stages_and_busy() {
        let stats = ServeStats {
            served: 4,
            examples: 8,
            batches: 2,
            busy: 3,
            lanes: 2,
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            queue_ms: vec![0.5; 4],
            exec_ms: vec![1.0; 4],
            reply_ms: vec![0.25; 4],
            lane_depth_max: vec![2, 1],
            elapsed_s: 2.0,
            ..ServeStats::default()
        };
        assert_eq!(stats.p50_ms(), 3.0);
        assert_eq!(stats.p99_ms(), 4.0);
        assert_eq!(stats.req_per_s(), 2.0);
        assert_eq!(stats.exec_p99_ms(), 1.0);
        let s = stats.summary();
        assert!(s.contains("p50") && s.contains("p99") && s.contains("req/s"), "{s}");
        assert!(s.contains("3 busy") && s.contains("2 lanes"), "{s}");
    }

    #[test]
    fn quant_for_respects_fp32_overrides() {
        let cfg = ServeCfg {
            quant: QuantMode::Int8,
            fp32_models: vec!["vgg8bn".into()],
            ..ServeCfg::default()
        };
        assert_eq!(cfg.quant_for("mlp128"), QuantMode::Int8);
        assert_eq!(cfg.quant_for("vgg8bn"), QuantMode::Fp32);
    }

    #[test]
    fn retry_hint_scales_with_depth_and_clamps() {
        let cfg = ServeCfg { max_delay: Duration::from_millis(2), ..ServeCfg::default() };
        let idle = retry_hint_ms(&cfg, 1, None);
        let deep = retry_hint_ms(&cfg, 16, Some(10.0));
        assert!(idle >= 1);
        assert!(deep > idle, "deeper queues hint longer waits");
        assert!(retry_hint_ms(&cfg, usize::MAX / 2, Some(1e12)) <= 60_000);
    }

    #[test]
    fn default_lanes_is_at_least_one() {
        assert!(default_lanes() >= 1);
    }
}
