//! The serving loop: nonblocking accept, per-connection polling,
//! micro-batched folded/quantized forwards, framed replies.
//!
//! Single-threaded by design — the forward pass dominates wall time
//! and is already bit-deterministic at any kernel thread count, so one
//! poll loop multiplexing every connection keeps reply order and
//! latency accounting simple while still serving concurrent clients
//! (each poll round visits every live connection).
//!
//! Protocol per connection: clients send `InferRequest` frames and
//! read `InferReply` frames; either side ends with `Shutdown`. A
//! malformed or invalid request earns a faulted `Shutdown` naming the
//! reason and the connection is dropped — the server itself never
//! exits on peer misbehavior.

use super::batcher::{Batcher, Pending};
use super::cache::PlanCache;
use super::{QuantMode, ServeModel};
use crate::net::{Msg, TcpTransport, Transport};
use crate::runtime::Engine;
use crate::util::math::percentile;
use anyhow::{Context, Result};
use std::net::TcpListener;
use std::time::{Duration, Instant};

/// Hard cap on examples per request, mirroring the decoder's guard in
/// `net::proto` so an admitted request can never out-size the wire.
pub const MAX_REQUEST_BATCH: usize = 4096;

/// How long one poll round waits on each connection for the *start* of
/// a frame. Small, so a round visits every connection quickly.
const POLL: Duration = Duration::from_millis(1);

#[derive(Debug, Clone)]
pub struct ServeCfg {
    pub quant: QuantMode,
    /// Seed + steps of the deterministic weight reconstruction
    /// ([`crate::train::serving_params`]) — clients that want to
    /// `--check` replies must use the same pair.
    pub seed: u64,
    pub steps: usize,
    /// Flush the micro-batch queue at this many queued examples.
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long.
    pub max_delay: Duration,
    /// LRU capacity of the prepared-plan cache.
    pub cache_cap: usize,
    /// Serve exactly this many requests, then return (tests, benches,
    /// CI smoke). `None` serves until the process dies.
    pub max_requests: Option<u64>,
    pub verbose: bool,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            quant: QuantMode::Int8,
            seed: 42,
            steps: 40,
            max_batch: 32,
            max_delay: Duration::from_millis(2),
            cache_cap: 4,
            max_requests: None,
            verbose: false,
        }
    }
}

/// Counters and latency samples from one `run_serve` call.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Requests answered with an `InferReply`.
    pub served: u64,
    /// Examples inside those requests.
    pub examples: u64,
    /// Forward passes (flushed micro-batches, per model group).
    pub batches: u64,
    /// Requests rejected with a faulted `Shutdown`.
    pub rejected: u64,
    /// Admission-to-reply latency of each served request, milliseconds.
    pub latencies_ms: Vec<f64>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub elapsed_s: f64,
}

impl ServeStats {
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 99.0)
    }

    pub fn req_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.served as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "served {} requests ({} examples) in {} forwards over {:.2}s | \
             p50 {:.3} ms, p99 {:.3} ms, {:.1} req/s | \
             cache {} hits / {} misses | {} rejected",
            self.served,
            self.examples,
            self.batches,
            self.elapsed_s,
            self.p50_ms(),
            self.p99_ms(),
            self.req_per_s(),
            self.cache_hits,
            self.cache_misses,
            self.rejected
        )
    }
}

/// Validate an admitted `InferRequest` against the model registry.
/// Decode guards already bounded `batch`; this adds existence and
/// exact input-size checks so the forward can never see a shape error.
fn validate(engine: &Engine, model: &str, batch: u32, x_len: usize) -> Result<(), String> {
    let entry = match engine.manifest.models.get(model) {
        Some(e) => e,
        None => return Err(format!("unknown model '{model}'")),
    };
    let numel: usize = entry.input_shape.iter().product();
    if batch == 0 || batch as usize > MAX_REQUEST_BATCH {
        return Err(format!("batch {batch} outside 1..={MAX_REQUEST_BATCH}"));
    }
    if x_len != batch as usize * numel {
        return Err(format!(
            "model '{model}': {x_len} input values, expected {} (batch {batch} x {numel})",
            batch as usize * numel
        ));
    }
    Ok(())
}

/// Send a faulted `Shutdown` naming `reason`, then drop the slot.
fn fault_drop(slot: &mut Option<Box<dyn Transport>>, reason: &str) {
    if let Some(t) = slot.as_mut() {
        let _ = t.send(&Msg::Shutdown { fault: true, reason: reason.to_string() });
    }
    *slot = None;
}

/// Run the serving loop on an already-bound listener until
/// `max_requests` is reached (never returns when it is `None`).
pub fn run_serve(listener: &TcpListener, cfg: &ServeCfg) -> Result<ServeStats> {
    listener.set_nonblocking(true).context("setting listener nonblocking")?;
    let engine = Engine::native()?;
    let mut cache = PlanCache::new(cfg.cache_cap);
    let mut batcher = Batcher::new(cfg.max_batch, cfg.max_delay);
    let mut conns: Vec<Option<Box<dyn Transport>>> = Vec::new();
    let mut stats = ServeStats::default();
    let started = Instant::now();

    loop {
        // Admit every connection waiting on the listener.
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => match TcpTransport::from_stream(stream) {
                    Ok(t) => conns.push(Some(Box::new(t))),
                    Err(e) => {
                        if cfg.verbose {
                            eprintln!("[serve] rejected connection: {e:#}");
                        }
                    }
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("accepting connection"),
            }
        }

        // One short poll per live connection.
        for (ci, slot) in conns.iter_mut().enumerate() {
            let Some(t) = slot.as_mut() else { continue };
            match t.recv_deadline(POLL) {
                Ok(None) => {}
                Ok(Some(Msg::InferRequest { id, model, batch, x })) => {
                    match validate(&engine, &model, batch, x.len()) {
                        Ok(()) => batcher.push(Pending {
                            conn: ci,
                            id,
                            model,
                            batch: batch as usize,
                            x,
                            arrived: Instant::now(),
                        }),
                        Err(reason) => {
                            stats.rejected += 1;
                            fault_drop(slot, &reason);
                        }
                    }
                }
                Ok(Some(Msg::Shutdown { .. })) => *slot = None,
                Ok(Some(other)) => {
                    stats.rejected += 1;
                    fault_drop(slot, &format!("unexpected message tag {}", other.tag()));
                }
                Err(_) => *slot = None, // peer hung up or sent garbage
            }
        }

        // Flush: group the FIFO drain by model, one forward per group.
        let now = Instant::now();
        if batcher.ready(now) {
            let drained = batcher.take_ready(now);
            let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
            for p in drained {
                match groups.iter_mut().find(|(m, _)| *m == p.model) {
                    Some((_, g)) => g.push(p),
                    None => groups.push((p.model.clone(), vec![p])),
                }
            }
            for (model, group) in groups {
                let prepared = cache.get_or_try_insert(&model, || {
                    ServeModel::prepare_named(&model, cfg.seed, cfg.steps, cfg.quant)
                });
                let sm = match prepared {
                    Ok(sm) => sm,
                    Err(e) => {
                        let reason = format!("preparing model '{model}': {e:#}");
                        for p in &group {
                            stats.rejected += 1;
                            if let Some(slot) = conns.get_mut(p.conn) {
                                fault_drop(slot, &reason);
                            }
                        }
                        continue;
                    }
                };
                let total: usize = group.iter().map(|p| p.batch).sum();
                let mut xs = Vec::with_capacity(total * sm.input_numel);
                for p in &group {
                    xs.extend_from_slice(&p.x);
                }
                let (preds, logits) = match sm.infer(&xs, total) {
                    Ok(out) => out,
                    Err(e) => {
                        // Validation should make this unreachable; if a
                        // forward still fails, fault the group, keep
                        // serving.
                        let reason = format!("forward failed for '{model}': {e:#}");
                        for p in &group {
                            stats.rejected += 1;
                            if let Some(slot) = conns.get_mut(p.conn) {
                                fault_drop(slot, &reason);
                            }
                        }
                        continue;
                    }
                };
                stats.batches += 1;
                let classes = sm.classes;
                let done = Instant::now();
                let mut preds = preds.into_iter();
                let mut logits = logits.into_iter();
                for p in group {
                    let reply = Msg::InferReply {
                        id: p.id,
                        classes: classes as u32,
                        preds: preds.by_ref().take(p.batch).collect(),
                        logits: logits.by_ref().take(p.batch * classes).collect(),
                    };
                    if let Some(slot) = conns.get_mut(p.conn) {
                        if let Some(t) = slot.as_mut() {
                            match t.send(&reply) {
                                Ok(()) => {
                                    stats.served += 1;
                                    stats.examples += p.batch as u64;
                                    stats
                                        .latencies_ms
                                        .push(done.saturating_duration_since(p.arrived).as_secs_f64() * 1e3);
                                }
                                Err(_) => *slot = None,
                            }
                        }
                    }
                }
                if cfg.verbose {
                    eprintln!(
                        "[serve] {model}: batch of {total} examples served ({} total requests)",
                        stats.served
                    );
                }
            }
        }

        if let Some(cap) = cfg.max_requests {
            if stats.served + stats.rejected >= cap && batcher.is_empty() {
                break;
            }
        }

        // Nothing to poll: sleep instead of spinning on accept().
        if conns.iter().all(|c| c.is_none()) {
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    stats.cache_hits = cache.hits;
    stats.cache_misses = cache.misses;
    stats.elapsed_s = started.elapsed().as_secs_f64();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_unknown_model_bad_batch_and_bad_len() {
        let engine = Engine::native().unwrap();
        let entry = engine.manifest.models.get("mlp128").unwrap();
        let numel: usize = entry.input_shape.iter().product();
        assert!(validate(&engine, "mlp128", 2, 2 * numel).is_ok());
        assert!(validate(&engine, "no-such-model", 1, numel).is_err());
        assert!(validate(&engine, "mlp128", 0, 0).is_err());
        assert!(validate(&engine, "mlp128", 5000, 5000 * numel).is_err());
        assert!(validate(&engine, "mlp128", 2, 2 * numel + 1).is_err());
    }

    #[test]
    fn stats_summary_reports_percentiles() {
        let stats = ServeStats {
            served: 4,
            examples: 8,
            batches: 2,
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            elapsed_s: 2.0,
            ..ServeStats::default()
        };
        assert_eq!(stats.p50_ms(), 3.0);
        assert_eq!(stats.p99_ms(), 4.0);
        assert_eq!(stats.req_per_s(), 2.0);
        let s = stats.summary();
        assert!(s.contains("p50") && s.contains("p99") && s.contains("req/s"), "{s}");
    }
}
