//! Bitmap sparse encoding: 1 presence bit per position + packed values.
//!
//! Beats CSR above ~ 1/64 density because the index cost is constant
//! (n/8 bytes) instead of 4 bytes per nonzero.

/// Bitmap-encoded sparse vector.
#[derive(Debug, Clone, PartialEq)]
pub struct BitmapVec {
    pub len: usize,
    /// ceil(len/8) presence bits, LSB-first within each byte.
    pub mask: Vec<u8>,
    pub values: Vec<f32>,
}

impl BitmapVec {
    pub fn encode(dense: &[f32]) -> Self {
        let mut mask = vec![0u8; dense.len().div_ceil(8)];
        let mut values = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                mask[i / 8] |= 1 << (i % 8);
                values.push(v);
            }
        }
        BitmapVec { len: dense.len(), mask, values }
    }

    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        let mut vi = 0;
        for i in 0..self.len {
            if self.mask[i / 8] & (1 << (i % 8)) != 0 {
                out[i] = self.values[vi];
                vi += 1;
            }
        }
        debug_assert_eq!(vi, self.values.len());
        out
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn encoded_bytes(&self) -> usize {
        encoded_bytes(self.len, self.nnz())
    }
}

/// Wire size: 4 (len) + ceil(n/8) mask + 4/value.
pub fn encoded_bytes(n: usize, nnz: usize) -> usize {
    4 + n.div_ceil(8) + 4 * nnz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn roundtrip_simple() {
        let dense = vec![0.0, 1.0, 0.0, 0.0, -3.5, 0.0, 0.0, 0.0, 9.0];
        let enc = BitmapVec::encode(&dense);
        assert_eq!(enc.nnz(), 3);
        assert_eq!(enc.decode(), dense);
    }

    #[test]
    fn roundtrip_property() {
        check("bitmap roundtrip == identity", 300, |g: &mut Gen| {
            let density = g.f32_in(0.0, 1.0);
            let dense = g.sparse_f32(0..=512, density);
            BitmapVec::encode(&dense).decode() == dense
        });
    }

    #[test]
    fn mask_bit_layout() {
        let enc = BitmapVec::encode(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
        assert_eq!(enc.mask, vec![0b1000_0001, 0b0000_0001]);
    }

    #[test]
    fn bytes_cheaper_than_csr_at_mid_density() {
        let n = 1024;
        let nnz = 400;
        assert!(encoded_bytes(n, nnz) < super::super::csr::encoded_bytes(n, nnz));
    }
}
