//! CSR-style sparse encodings: [`CsrVec`] (one flat vector — the wire
//! codec) and [`CsrMat`] (row-major matrix with shared index/value
//! buffers — the fused-quantizer output the backward GEMMs consume),
//! unified for the kernels by the [`SparseRows`] row-access trait.
//!
//! Decode is exact — the codec must round-trip bit-perfectly because
//! the server averages decoded gradients.

/// Sparse vector encoding.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrVec {
    pub len: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrVec {
    /// Encode a dense slice (exact zeros are dropped).
    pub fn encode(dense: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        CsrVec { len: dense.len(), indices, values }
    }

    /// Decode into a fresh dense vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.decode_into(&mut out);
        out
    }

    /// Decode into an existing buffer (zeroed first).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        out.fill(0.0);
        for (&i, &v) in self.indices.iter().zip(self.values.iter()) {
            out[i as usize] = v;
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Wire size in bytes: 4 (len) + 4/idx + 4/value.
    pub fn encoded_bytes(&self) -> usize {
        encoded_bytes(self.len, self.nnz())
    }

    /// Accumulate `alpha * self` into a dense buffer without
    /// materialising the decoded vector (server-side hot path: cost is
    /// O(nnz), which is where Eq. 12's savings show up in aggregation).
    pub fn axpy_into(&self, alpha: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        for (&i, &v) in self.indices.iter().zip(self.values.iter()) {
            out[i as usize] += alpha * v;
        }
    }
}

/// Wire size for (n, nnz) without building the encoding.
pub fn encoded_bytes(_n: usize, nnz: usize) -> usize {
    4 + 8 * nnz
}

/// Row-major CSR matrix: `rows x cols` with one shared index buffer,
/// one shared value buffer, and `rows + 1` prefix offsets. This is what
/// the fused NSD quantizer emits (`quant::nsd_csr_rows`) — no per-row
/// `Vec`s, so the whole encoding lives in three arena-recyclable
/// buffers and a steady-state grad step allocates nothing for it.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMat {
    pub rows: usize,
    pub cols: usize,
    /// `rows + 1` offsets into `indices` / `values` (ascending).
    pub row_ptr: Vec<u32>,
    /// Column indices, sorted ascending within each row.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMat {
    /// Encode a dense `rows x cols` tensor (exact zeros dropped).
    pub fn encode_rows(dense: &[f32], rows: usize, cols: usize) -> CsrMat {
        assert_eq!(dense.len(), rows * cols);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for r in 0..rows {
            for (c, &v) in dense[r * cols..(r + 1) * cols].iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        CsrMat { rows, cols, row_ptr, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (sorted column indices, values) of row `r`.
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Decode into an existing dense buffer (zeroed first).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols);
        out.fill(0.0);
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            let dst = &mut out[r * self.cols..(r + 1) * self.cols];
            for (&c, &v) in idx.iter().zip(val.iter()) {
                dst[c as usize] = v;
            }
        }
    }

    /// Decode into a fresh dense vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        self.decode_into(&mut out);
        out
    }
}

/// Uniform row access over sparse encodings, so the backward GEMM
/// kernels run unchanged on `&[CsrVec]` (per-row vectors, the wire
/// path and the tests' encoding) and [`CsrMat`] (the fused-quantizer
/// output). Rows must present sorted indices — the column-partitioned
/// param GEMM binary-searches them.
pub trait SparseRows: Sync {
    fn n_rows(&self) -> usize;
    /// (sorted indices, values) of row `r`.
    fn row(&self, r: usize) -> (&[u32], &[f32]);
    /// Total nonzeros (the threaded drivers' fan-out estimate).
    fn nnz_total(&self) -> usize {
        (0..self.n_rows()).map(|r| self.row(r).0.len()).sum()
    }
}

impl SparseRows for [CsrVec] {
    fn n_rows(&self) -> usize {
        self.len()
    }
    fn row(&self, r: usize) -> (&[u32], &[f32]) {
        (&self[r].indices, &self[r].values)
    }
}

impl SparseRows for Vec<CsrVec> {
    fn n_rows(&self) -> usize {
        self.len()
    }
    fn row(&self, r: usize) -> (&[u32], &[f32]) {
        (&self[r].indices, &self[r].values)
    }
}

impl SparseRows for CsrMat {
    fn n_rows(&self) -> usize {
        self.rows
    }
    fn row(&self, r: usize) -> (&[u32], &[f32]) {
        CsrMat::row(self, r)
    }
    fn nnz_total(&self) -> usize {
        self.nnz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn roundtrip_simple() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let enc = CsrVec::encode(&dense);
        assert_eq!(enc.nnz(), 2);
        assert_eq!(enc.decode(), dense);
    }

    #[test]
    fn roundtrip_property() {
        check("csr roundtrip == identity", 300, |g: &mut Gen| {
            let density = g.f32_in(0.0, 1.0);
            let dense = g.sparse_f32(0..=512, density);
            CsrVec::encode(&dense).decode() == dense
        });
    }

    #[test]
    fn axpy_matches_decode_then_axpy() {
        check("csr axpy == decode+axpy", 200, |g: &mut Gen| {
            let dense = g.sparse_f32(1..=256, 0.3);
            let enc = CsrVec::encode(&dense);
            let mut a = vec![0.0f32; dense.len()];
            enc.axpy_into(0.5, &mut a);
            let b: Vec<f32> = dense.iter().map(|v| 0.5 * v).collect();
            a.iter().zip(b.iter()).all(|(x, y)| (x - y).abs() < 1e-6)
        });
    }

    #[test]
    fn empty_and_all_zero() {
        assert_eq!(CsrVec::encode(&[]).decode(), Vec::<f32>::new());
        let z = CsrVec::encode(&[0.0; 8]);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.decode(), vec![0.0; 8]);
        assert_eq!(z.encoded_bytes(), 4);
    }

    #[test]
    fn bytes_formula() {
        let dense = vec![1.0; 10];
        assert_eq!(CsrVec::encode(&dense).encoded_bytes(), 4 + 80);
    }
}
