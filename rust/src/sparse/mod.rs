//! Sparse + quantized gradient codecs.
//!
//! In the paper's distributed setting (§4.3, batch 1 per node) the
//! NSD-sparsified pre-activation gradients make the *weight gradients*
//! sparse too, so workers can ship compressed gradients to the parameter
//! server.  These codecs implement that wire format and provide the
//! byte accounting the communication-savings analysis uses:
//!
//! * [`csr`]    — index+value encoding (good below ~30% density)
//! * [`bitmap`] — 1 bit/position presence mask + values (good above)
//! * [`packed`] — integer-level packing of Delta-grid tensors at the
//!   worst-case bitwidth (Fig. 6b: levels fit in <= 8 bits)

pub mod bitmap;
pub mod csr;
pub mod packed;

pub use bitmap::BitmapVec;
pub use csr::{CsrMat, CsrVec, SparseRows};
pub use packed::PackedGrid;

/// Encoded sizes in bytes for a dense f32 tensor of `n` elements.
pub fn dense_bytes(n: usize) -> usize {
    4 * n
}

/// Pick the cheaper of CSR / bitmap for the given density; returns
/// (encoding name, bytes).  The crossover is the codec-selection policy
/// the coordinator's comm channel uses.
pub fn best_encoding_bytes(n: usize, nnz: usize) -> (&'static str, usize) {
    let csr = csr::encoded_bytes(n, nnz);
    let bmp = bitmap::encoded_bytes(n, nnz);
    let dense = dense_bytes(n);
    let mut best = ("dense", dense);
    if csr < best.1 {
        best = ("csr", csr);
    }
    if bmp < best.1 {
        best = ("bitmap", bmp);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossovers() {
        // fully dense: dense wins
        assert_eq!(best_encoding_bytes(1000, 1000).0, "dense");
        // very sparse: csr wins
        assert_eq!(best_encoding_bytes(1000, 10).0, "csr");
        // mid density: bitmap beats csr (indices cost 4B each)
        let (name, _) = best_encoding_bytes(1000, 500);
        assert_eq!(name, "bitmap");
    }
}
