//! Integer-level bit-packing of Delta-grid tensors.
//!
//! NSD output values are exact integer multiples of Delta; Table 1 and
//! Fig. 6b show the levels fit in <= 8 bits.  This codec stores
//! (Delta, bitwidth, packed two's-complement levels) — the format a
//! dither-aware accelerator ([25] in the paper) would consume, and the
//! honest way to measure the "non-zero values below 8 bits" claim on
//! our own tensors.

use crate::util::math::bitwidth_for_level;

/// Bit-packed quantized tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedGrid {
    pub len: usize,
    pub delta: f32,
    /// Bits per level (sign included); 0 means all-zero tensor.
    pub bits: u32,
    pub payload: Vec<u8>,
}

impl PackedGrid {
    /// Encode a tensor whose values are integer multiples of `delta`.
    /// Returns None if any value is off-grid (caller bug or delta=0 path).
    pub fn encode(dense: &[f32], delta: f32) -> Option<Self> {
        if delta <= 0.0 {
            return None;
        }
        let mut levels = Vec::with_capacity(dense.len());
        let mut max_abs = 0i64;
        for &v in dense {
            let l = v / delta;
            let li = l.round() as i64;
            if (l - li as f32).abs() > 1e-3 {
                return None; // off-grid
            }
            max_abs = max_abs.max(li.abs());
            levels.push(li);
        }
        let bits = bitwidth_for_level(max_abs as f32);
        let mut payload = vec![0u8; (dense.len() * bits as usize).div_ceil(8)];
        if bits > 0 {
            for (i, &l) in levels.iter().enumerate() {
                // two's complement in `bits` bits
                let u = (l & ((1i64 << bits) - 1)) as u64;
                write_bits(&mut payload, i * bits as usize, bits, u);
            }
        }
        Some(PackedGrid { len: dense.len(), delta, bits, payload })
    }

    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        if self.bits == 0 {
            return out;
        }
        for (i, o) in out.iter_mut().enumerate() {
            let u = read_bits(&self.payload, i * self.bits as usize, self.bits);
            // sign-extend
            let shift = 64 - self.bits;
            let l = ((u << shift) as i64) >> shift;
            *o = l as f32 * self.delta;
        }
        out
    }

    /// Wire size: 4 (len) + 4 (delta) + 1 (bits) + payload.
    pub fn encoded_bytes(&self) -> usize {
        9 + self.payload.len()
    }
}

fn write_bits(buf: &mut [u8], bit_off: usize, nbits: u32, value: u64) {
    for k in 0..nbits as usize {
        if value >> k & 1 != 0 {
            let b = bit_off + k;
            buf[b / 8] |= 1 << (b % 8);
        }
    }
}

fn read_bits(buf: &[u8], bit_off: usize, nbits: u32) -> u64 {
    let mut v = 0u64;
    for k in 0..nbits as usize {
        let b = bit_off + k;
        if buf[b / 8] & (1 << (b % 8)) != 0 {
            v |= 1 << k;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn roundtrip_simple() {
        let delta = 0.25;
        let dense = vec![0.0, 0.5, -0.75, 1.0, 0.0];
        let enc = PackedGrid::encode(&dense, delta).unwrap();
        assert_eq!(enc.bits, 4); // level 4 -> sign + 3
        assert_eq!(enc.decode(), dense);
    }

    #[test]
    fn roundtrip_property() {
        check("packed roundtrip == identity", 300, |g: &mut Gen| {
            let delta = g.f32_in(0.01, 1.0);
            let n = g.usize_in(0..=256);
            let dense: Vec<f32> = (0..n)
                .map(|_| {
                    let level = (g.f32_in(-100.0, 100.0)).round();
                    level * delta
                })
                .collect();
            match PackedGrid::encode(&dense, delta) {
                Some(enc) => {
                    let dec = enc.decode();
                    dense.iter().zip(dec.iter()).all(|(a, b)| (a - b).abs() < delta * 1e-3)
                }
                None => false,
            }
        });
    }

    #[test]
    fn all_zero_costs_header_only() {
        let enc = PackedGrid::encode(&[0.0; 100], 0.5).unwrap();
        assert_eq!(enc.bits, 0);
        assert_eq!(enc.encoded_bytes(), 9);
        assert_eq!(enc.decode(), vec![0.0; 100]);
    }

    #[test]
    fn off_grid_rejected() {
        assert!(PackedGrid::encode(&[0.3], 0.25).is_none());
        assert!(PackedGrid::encode(&[1.0], 0.0).is_none());
    }

    #[test]
    fn eight_bit_claim_size() {
        // 1000 values at <=8 bits must fit in ~1009 bytes vs 4000 dense
        let delta = 0.1;
        let dense: Vec<f32> = (0..1000).map(|i| ((i % 255) as f32 - 127.0) * delta).collect();
        let enc = PackedGrid::encode(&dense, delta).unwrap();
        assert_eq!(enc.bits, 8);
        assert!(enc.encoded_bytes() <= 1009);
    }
}
