//! Minimal dense f32 tensor used on the coordinator side.
//!
//! The heavy math lives in the AOT-compiled XLA artifacts; the
//! coordinator only needs a host-side container for parameters,
//! gradients and batches, plus the handful of elementwise ops the
//! optimizer and the parameter server perform (axpy-style updates,
//! averaging).  Row-major, contiguous, f32 only — deliberately not a
//! general ndarray.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from raw data; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Shape as i64 for the XLA literal API.
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }

    /// First element (useful for scalar outputs).
    pub fn item(&self) -> f32 {
        self.data[0]
    }

    /// In-place `self += alpha * other` (the optimizer/server hot op).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Fraction of exact zeros (sparsity of the tensor itself).
    pub fn sparsity(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|&&v| v == 0.0).count();
        z as f32 / self.data.len() as f32
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Max |element|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

/// Elementwise average of per-node gradients into `acc` (server-side
/// aggregation primitive; `acc` must be zeroed or hold a partial sum).
pub fn accumulate_mean(acc: &mut [Tensor], node: &[Tensor], inv_n: f32) {
    debug_assert_eq!(acc.len(), node.len());
    for (a, g) in acc.iter_mut().zip(node.iter()) {
        a.axpy(inv_n, g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.sparsity(), 1.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![10.0, 10.0, 10.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6.0, 7.0, 8.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 14.0, 16.0]);
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(&[4], vec![0.0, -2.0, 0.0, 1.0]);
        assert_eq!(t.sparsity(), 0.5);
        assert_eq!(t.abs_max(), 2.0);
        assert!((t.mean() - (-0.25)).abs() < 1e-6);
        assert!((t.norm() - (5.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn accumulate_mean_averages() {
        let mut acc = vec![Tensor::zeros(&[2])];
        let g1 = vec![Tensor::from_vec(&[2], vec![2.0, 4.0])];
        let g2 = vec![Tensor::from_vec(&[2], vec![4.0, 8.0])];
        accumulate_mean(&mut acc, &g1, 0.5);
        accumulate_mean(&mut acc, &g2, 0.5);
        assert_eq!(acc[0].data(), &[3.0, 6.0]);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(7.5).item(), 7.5);
    }
}
