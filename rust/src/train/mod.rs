//! Single-node trainer: the paper's Table 1 / Fig. 3 / Fig. 4 loop.
//!
//! Drives whichever backend the engine loaded, step by step: shuffled
//! batches from the data substrate, gradient execution through the
//! [`crate::runtime::Backend`] dispatch, SGD-momentum updates in rust,
//! periodic test-set evaluation, full telemetry into
//! [`crate::metrics::History`].

use crate::data::{BatchIter, Dataset};
use crate::metrics::{History, StepRecord};
use crate::optim::{Sgd, SgdConfig};
use crate::runtime::{Engine, TrainingSession};
use crate::tensor::Tensor;
use anyhow::Result;

/// Training run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    /// "baseline" | "dithered" | "int8" | "int8_dithered" | "meprop_k<N>"
    pub method: String,
    /// Dither scale factor s (ignored by non-dithered methods).
    pub s: f32,
    pub steps: usize,
    pub batch: usize,
    pub opt: SgdConfig,
    /// Evaluate on the test split every N steps (0 = only at the end).
    pub eval_every: usize,
    pub seed: u64,
    /// Print progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    pub fn quick(model: &str, method: &str, s: f32, steps: usize) -> Self {
        TrainConfig {
            model: model.to_string(),
            method: method.to_string(),
            s,
            steps,
            batch: 64,
            opt: SgdConfig::paper(0.05, steps * 2 / 3),
            eval_every: 0,
            seed: 42,
            verbose: false,
        }
    }
}

/// Result of a completed run.
pub struct TrainResult {
    pub params: Vec<Tensor>,
    pub history: History,
    /// Final test accuracy in [0, 1].
    pub test_acc: f32,
}

/// Run a single-node training job end to end.
pub fn train(engine: &Engine, data: &Dataset, cfg: &TrainConfig) -> Result<TrainResult> {
    let session = engine.training_session(&cfg.model, &cfg.method, cfg.batch)?;
    let mut params = engine.init_params(&cfg.model, cfg.seed as u32)?;
    // BN running-stat slots are assigned from the grad slots, not
    // SGD-stepped (Backend contract)
    let mut opt = Sgd::new(cfg.opt, &params).with_stat_slots(&session.entry.params);
    let mut iter = BatchIter::new(&data.train, cfg.batch, cfg.seed);
    let mut history = History::default();

    for step in 0..cfg.steps {
        iter.next_batch(&data.train);
        let out = session.grad(&params, &iter.x, &iter.y, step_seed(cfg.seed, step), cfg.s)?;
        history.push(StepRecord {
            step,
            loss: out.loss,
            acc: out.correct / cfg.batch as f32,
            sparsity: out.mean_sparsity(),
            bits: out.max_bitwidth(),
            layer_sparsity: out.sparsity.clone(),
        });
        opt.apply(&mut params, &out.grads);

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let acc = evaluate(&session, &params, data)?;
            history.push_eval(step + 1, acc);
            if cfg.verbose {
                println!(
                    "[{}/{}] {} step {step}: loss {:.4} test-acc {:.4} sparsity {:.3} bits {}",
                    cfg.model,
                    cfg.method,
                    cfg.s,
                    out.loss,
                    acc,
                    history.mean_sparsity(),
                    history.max_bits(),
                );
            }
        }
    }

    let test_acc = evaluate(&session, &params, data)?;
    history.push_eval(cfg.steps, test_acc);
    Ok(TrainResult { params, history, test_acc })
}

/// Accuracy on the test split in [0, 1].
pub fn evaluate(session: &TrainingSession, params: &[Tensor], data: &Dataset) -> Result<f32> {
    let eb = session.entry.eval_batch;
    let usable = (data.test.len() / eb) * eb;
    anyhow::ensure!(usable > 0, "test split smaller than eval batch {eb}");
    let out = session.eval_dataset(params, &data.test.images, &data.test.labels)?;
    Ok(out.correct / usable as f32)
}

/// Deterministic serving weights: seeded init plus a short, fixed
/// training run on the model's registry dataset (`steps == 0` skips
/// straight to the init). Every process calling this with the same
/// `(model, seed, steps)` reconstructs bit-identical parameters — the
/// kernels are bit-identical across variants and thread counts, the
/// data substrate and batch order are seeded, and SGD is exact — so a
/// `serve` server and an `infer --check` client agree without any
/// checkpoint crossing the wire. The short run also moves the BN
/// running statistics off their zero/one init (making the serving-side
/// fold non-trivial) and grows real logit margins, without which an
/// int8-vs-fp32 top-1 agreement gate would measure coin flips.
pub fn serving_params(
    engine: &Engine,
    model: &str,
    seed: u64,
    steps: usize,
) -> Result<Vec<Tensor>> {
    if steps == 0 {
        return engine.init_params(model, seed as u32);
    }
    let entry = engine
        .manifest
        .models
        .get(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
    let data = crate::data::build(&entry.dataset, 512, entry.eval_batch, seed ^ 0x5e37e);
    let cfg = TrainConfig {
        model: model.to_string(),
        method: "baseline".to_string(),
        s: 0.0,
        steps,
        batch: 32,
        opt: SgdConfig::plain(entry.lr.unwrap_or(0.05)),
        eval_every: 0,
        seed,
        verbose: false,
    };
    Ok(train(engine, &data, &cfg)?.params)
}

/// Per-step dither seed: decorrelate steps without colliding with the
/// per-layer folding done in L2.
pub fn step_seed(run_seed: u64, step: usize) -> u32 {
    let mut z = run_seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(step as u64)
        .wrapping_mul(0xBF58476D1CE4E5B9);
    z ^= z >> 31;
    z as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_seeds_distinct() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..10_000 {
            assert!(seen.insert(step_seed(42, step)));
        }
    }

    #[test]
    fn quick_config_defaults() {
        let c = TrainConfig::quick("mlp500", "dithered", 2.0, 300);
        assert_eq!(c.batch, 64);
        assert_eq!(c.opt.momentum, 0.9);
        assert_eq!(c.steps, 300);
    }
}
