//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, bare boolean `--flag`, and
//! positional arguments.  Typed getters with defaults + a `usage` helper
//! keep the binaries self-documenting.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags seen without a value (`--quick`).
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.switches.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// A flag that must be present (subcommands with no sane default,
    /// e.g. `dist-worker --connect HOST:PORT`).
    pub fn require(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }

    /// Comma-separated list value.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn kinds_of_flags() {
        let a = parse("train --model mlp500 --s=2.5 --quick --steps 300 pos1");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.get("model"), Some("mlp500"));
        assert_eq!(a.f32_or("s", 0.0), 2.5);
        assert_eq!(a.usize_or("steps", 0), 300);
        assert!(a.has("quick"));
        assert!(!a.has("absent"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.str_or("model", "lenet5"), "lenet5");
        assert_eq!(a.usize_or("nodes", 4), 4);
        assert_eq!(a.u64_or("seed", 9), 9);
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--quick --model mlp500");
        assert!(a.has("quick"));
        assert_eq!(a.get("model"), Some("mlp500"));
    }

    #[test]
    fn require_present_and_missing() {
        let a = parse("--connect 127.0.0.1:7461");
        assert_eq!(a.require("connect").unwrap(), "127.0.0.1:7461");
        let err = a.require("bind").unwrap_err();
        assert!(err.to_string().contains("--bind"));
    }

    #[test]
    fn list_values() {
        let a = parse("--methods baseline,dithered");
        assert_eq!(a.list_or("methods", &[]), vec!["baseline", "dithered"]);
        assert_eq!(a.list_or("models", &["x"]), vec!["x"]);
    }

    #[test]
    fn negative_number_values() {
        // "--lr -0.1": '-0.1' does not start with '--' so it binds as value
        let a = parse("--lr -0.1");
        assert_eq!(a.f32_or("lr", 0.0), -0.1);
    }
}
