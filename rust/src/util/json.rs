//! Minimal JSON parser + writer.
//!
//! Parses the subset of JSON the AOT manifest uses (objects, arrays,
//! strings, numbers, booleans, null) — which happens to be all of JSON.
//! Recursive-descent, UTF-8, `\uXXXX` escapes supported (surrogate pairs
//! outside the manifest's character set are rejected rather than
//! mangled).  Also provides a compact serializer used by the experiment
//! harnesses to persist results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns a descriptive error (manifest debugging).
    pub fn req(&self, key: &str) -> Result<&Value, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape: {e}"))?;
                            let c = char::from_u32(code)
                                .ok_or_else(|| format!("surrogate \\u{hex} unsupported"))?;
                            out.push(c);
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other.map(|b| b as char))),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {} (found {:?})",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {} (found {:?})",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn parse_multibyte_utf8() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"mlp500":{"params":[{"name":"w","shape":[784,500]}],"n":3.5}},"ok":true}"#;
        let v = parse(src).unwrap();
        let again = parse(&v.to_json()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
    }
}
