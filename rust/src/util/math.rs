//! Numerical helpers for the analytic cost/sparsity models (Fig. 2,
//! Eq. 12): error function, standard normal CDF, midpoint quadrature.

/// Error function, Abramowitz & Stegun 7.1.26 (|eps| <= 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Midpoint-rule integral of `f` over [a, b] with `n` panels.
pub fn integrate<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, n: usize) -> f64 {
    let h = (b - a) / n as f64;
    (0..n).map(|i| f(a + (i as f64 + 0.5) * h)).sum::<f64>() * h
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Nearest-rank percentile over unsorted samples (`p` in [0, 100],
/// linear index rounding; 0.0 for an empty slice). Serving latency
/// summaries use this for p50/p99.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0).clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
    v.get(rank).copied().unwrap_or(0.0)
}

/// Worst-case bitwidth to represent signed integer levels up to
/// `max_abs_level` (Fig. 6b): sign bit + magnitude bits; 0 levels need 0
/// bits (everything quantized away).
pub fn bitwidth_for_level(max_abs_level: f32) -> u32 {
    let m = max_abs_level.round() as u64;
    if m == 0 {
        0
    } else {
        1 + (64 - m.leading_zeros() as u64) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_rank_selection() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 99.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn phi_symmetry() {
        assert!((phi(0.0) - 0.5).abs() < 1e-9);
        for x in [0.3, 1.1, 2.5] {
            assert!((phi(x) + phi(-x) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn integrate_parabola() {
        let v = integrate(|x| x * x, 0.0, 1.0, 10_000);
        assert!((v - 1.0 / 3.0).abs() < 1e-7, "{v}");
    }

    #[test]
    fn stats_helpers() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((std_dev(&xs) - 1.29099).abs() < 1e-4);
    }

    #[test]
    fn bitwidths() {
        assert_eq!(bitwidth_for_level(0.0), 0);
        assert_eq!(bitwidth_for_level(1.0), 2); // sign + 1
        assert_eq!(bitwidth_for_level(3.0), 3);
        assert_eq!(bitwidth_for_level(127.0), 8);
        assert_eq!(bitwidth_for_level(128.0), 9);
    }
}
