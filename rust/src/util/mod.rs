//! Substrate utilities reimplemented in-repo because the offline build
//! environment vendors only the `xla` crate closure (DESIGN.md §6):
//!
//! * [`json`] — minimal JSON parser/serializer (no `serde_json`)
//! * [`cli`] — argument parsing (no `clap`)
//! * [`rng`] — SplitMix64 PRNG + distributions (no `rand`)
//! * [`prop`] — property-testing harness (no `proptest`)
//! * [`math`] — erf / normal CDF / quadrature for the analytic models

pub mod cli;
pub mod json;
pub mod math;
pub mod prop;
pub mod rng;
