//! Mini property-testing harness (no `proptest` in the offline vendor
//! set).
//!
//! `check` runs a predicate over `n` pseudo-random cases derived from a
//! base seed; on failure it retries with progressively simpler sizes
//! (a lightweight stand-in for shrinking) and panics with the failing
//! seed so the case is reproducible:
//!
//! ```no_run
//! use ditherprop::util::prop::{check, Gen};
//! check("sorting is idempotent", 100, |g: &mut Gen| {
//!     let mut v = g.vec_f32(0..=64, -10.0, 10.0);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let w = { let mut w = v.clone(); w.sort_by(|a, b| a.partial_cmp(b).unwrap()); w };
//!     v == w
//! });
//! ```

use super::rng::Rng;
use std::ops::RangeInclusive;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0, 1]; grows over the run so early cases are small
    /// (cheap shrink-ish behaviour: failures usually reproduce small).
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        if hi == lo {
            return lo;
        }
        // scale the upper end by the size hint, but keep at least lo+1
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.below(span.max(1) + 1).min(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: RangeInclusive<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Sparse vector: each entry nonzero with probability `density`.
    pub fn sparse_f32(&mut self, len: RangeInclusive<usize>, density: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| {
                if self.rng.uniform() < density {
                    self.rng.normal()
                } else {
                    0.0
                }
            })
            .collect()
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed.
pub fn check<F: Fn(&mut Gen) -> bool>(name: &str, cases: u64, prop: F) {
    let base = 0xD17E_12B0_5EEDu64;
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let size = ((i + 1) as f64 / cases as f64).min(1.0);
        let mut g = Gen::new(seed, size);
        if !prop(&mut g) {
            // Re-run at smaller sizes to report the simplest repro we find.
            for frac in [0.1, 0.25, 0.5] {
                let mut g2 = Gen::new(seed, frac);
                if !prop(&mut g2) {
                    panic!(
                        "property '{name}' failed (seed={seed:#x}, size={frac}); \
                         rerun with Gen::new({seed:#x}, {frac})"
                    );
                }
            }
            panic!("property '{name}' failed (seed={seed:#x}, size={size})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is nonnegative", 200, |g| g.f32_in(-5.0, 5.0).abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_seed() {
        check("always-false", 10, |_| false);
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 300, |g| {
            let n = g.usize_in(1..=40);
            let v = g.vec_f32(0..=n, -1.0, 1.0);
            n >= 1 && n <= 40 && v.iter().all(|x| (-1.0..1.0).contains(x))
        });
    }

    #[test]
    fn sparse_density_extremes() {
        let mut g = Gen::new(1, 1.0);
        assert!(g.sparse_f32(64..=64, 0.0).iter().all(|&x| x == 0.0));
        let mut g = Gen::new(2, 1.0);
        assert!(g.sparse_f32(64..=64, 1.0).iter().all(|&x| x != 0.0));
    }
}
