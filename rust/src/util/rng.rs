//! SplitMix64 PRNG + the distributions the coordinator needs.
//!
//! Deterministic, seedable, dependency-free (no `rand` in the offline
//! vendor set).  Used for dataset synthesis, shuffling, the
//! property-testing harness, and — on the native backend — as the
//! counter RNG behind the NSD dither signal (`quant::nsd_host`, seeded
//! per (step, layer)).  Under the XLA backend the dither signal comes
//! from the L1 kernel's in-kernel hash RNG instead.

/// SplitMix64: tiny, fast, passes BigCrush on its output function.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.uniform() + 1e-9).min(1.0 - 1e-9);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_unbiased() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(1);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
