//! Integration: the distributed coordinator over real TCP sockets.
//!
//! Three claims pinned here:
//!  1. a server + 2 worker *processes-worth* of protocol over
//!     127.0.0.1 ephemeral ports trains end to end (loss decreases)
//!     and moves fewer measured bytes than dense gradients would,
//!  2. a channel-transport run and a TCP-loopback run with the same
//!     seeds produce bit-identical parameter vectors (the transport is
//!     semantically invisible),
//!  3. a worker that goes silent is dropped as a straggler and the run
//!     completes with the survivors.

use ditherprop::coordinator::{run_distributed, serve, serve_tcp, worker_loop, DistConfig};
use ditherprop::data::DataSpec;
use ditherprop::net::{ChannelTransport, Msg, TcpTransport, Transport};
use ditherprop::optim::{LrSchedule, SgdConfig};
use std::net::TcpListener;
use std::time::Duration;

/// A directory that never hosts AOT artifacts, so every engine load
/// serves the built-in native zoo.
fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/native-zoo").to_string()
}

fn cfg(nodes: usize, rounds: usize, spec: &DataSpec) -> DistConfig {
    DistConfig {
        artifacts_dir: artifacts(),
        model: "mlp128".into(),
        method: "dithered".into(),
        s: 3.0,
        nodes,
        rounds,
        opt: SgdConfig { lr: LrSchedule::constant(0.02), momentum: 0.9, weight_decay: 5e-4 },
        seed: 9,
        verbose: false,
        data: Some(spec.clone()),
        round_timeout: Duration::from_secs(20),
    }
}

/// Spawn `n` worker threads that connect to `addr` over real TCP and
/// regenerate their shards from the Welcome's DataSpec — exactly what
/// `dist-worker` processes do, minus the fork/exec.
fn spawn_tcp_workers(
    addr: std::net::SocketAddr,
    n: usize,
) -> Vec<std::thread::JoinHandle<anyhow::Result<()>>> {
    (0..n)
        .map(|_| {
            std::thread::spawn(move || {
                let link = TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10))?;
                worker_loop(Box::new(link), &artifacts(), None)
            })
        })
        .collect()
}

#[test]
fn tcp_loopback_two_workers_learn_and_compress() {
    let spec = DataSpec::new("digits", 512, 512, 6);
    let ds = spec.build();
    let cfg = cfg(2, 60, &spec);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_tcp_workers(addr, 2);
    let res = serve_tcp(&listener, &ds, &cfg).unwrap();
    for w in workers {
        w.join().unwrap().unwrap();
    }

    assert_eq!(res.comm.rounds, 60);
    assert_eq!(res.live_workers, 2);
    // learning: early-round loss above late-round loss
    let first = res.history.steps[..15].iter().map(|r| r.loss).sum::<f32>() / 15.0;
    let last = res.history.steps[45..].iter().map(|r| r.loss).sum::<f32>() / 15.0;
    assert!(last < first, "TCP loss not decreasing: {first} -> {last}");
    assert!(res.mean_sparsity > 0.5, "sparsity {}", res.mean_sparsity);
    // measured wire bytes (framing, handshake and heartbeats included)
    // must beat shipping dense f32 gradients
    assert!(res.comm.wire_up_bytes > 0, "byte counters never absorbed");
    assert!(
        res.comm.wire_up_bytes < res.comm.up_bytes_dense as u64,
        "measured {} wire bytes >= {} dense bytes",
        res.comm.wire_up_bytes,
        res.comm.up_bytes_dense
    );
    assert!(
        res.comm.measured_up_savings() > 1.5,
        "measured savings only x{:.2}",
        res.comm.measured_up_savings()
    );
}

#[test]
fn channel_and_tcp_runs_are_bit_identical() {
    let spec = DataSpec::new("digits", 384, 256, 11);
    let ds = spec.build();
    let cfg = cfg(2, 25, &spec);

    // channel-transport run (single process, worker threads)
    let chan = run_distributed(&ds, &cfg).unwrap();

    // TCP-loopback run, same seeds/config
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_tcp_workers(addr, 2);
    let tcp = serve_tcp(&listener, &ds, &cfg).unwrap();
    for w in workers {
        w.join().unwrap().unwrap();
    }

    assert_eq!(
        chan.params, tcp.params,
        "channel vs TCP parameter vectors diverged after {} rounds",
        cfg.rounds
    );
    assert_eq!(chan.test_acc, tcp.test_acc);
    assert_eq!(chan.comm.up_bytes, tcp.comm.up_bytes, "analytic codec bytes must match");
    // per-round losses identical too (same examples, same dither)
    for (a, b) in chan.history.steps.iter().zip(tcp.history.steps.iter()) {
        assert_eq!(a.loss, b.loss, "loss diverged at round {}", a.step);
    }
}

#[test]
fn heartbeat_spammer_is_dropped_not_waited_on() {
    // A peer that keeps acking but never uploads must not be able to
    // wedge the gather loop by resetting its deadline forever: the
    // second heartbeat in one round is a protocol violation and drops
    // the worker immediately (no timeout wait — keep round_timeout
    // large to prove the drop is cap-driven, not deadline-driven).
    let spec = DataSpec::new("digits", 256, 256, 5);
    let ds = spec.build();
    let mut cfg = cfg(2, 5, &spec);
    cfg.round_timeout = Duration::from_secs(30);

    let (real_server_side, real_worker_side) = ChannelTransport::pair("real");
    let shard = ds.train.shard(0, 2);
    let real = std::thread::spawn(move || {
        worker_loop(Box::new(real_worker_side), &artifacts(), Some(shard))
    });

    let (spam_server_side, mut spam_link) = ChannelTransport::pair("spam");
    let spam = std::thread::spawn(move || {
        spam_link
            .send(&Msg::Hello {
                proto: ditherprop::net::PROTO_VERSION,
                platform: "spam".into(),
                features: vec![],
            })
            .unwrap();
        let node = match spam_link.recv().unwrap() {
            Msg::Welcome(w) => w.node,
            other => panic!("expected Welcome, got tag {}", other.tag()),
        };
        loop {
            match spam_link.recv() {
                Ok(Msg::Params { round, .. }) => {
                    for _ in 0..5 {
                        if spam_link.send(&Msg::Heartbeat { node, round }).is_err() {
                            return; // dropped by the server, as expected
                        }
                    }
                }
                Ok(_) => {}
                Err(_) => return,
            }
        }
    });

    let links = vec![
        Some(Box::new(real_server_side) as Box<dyn Transport>),
        Some(Box::new(spam_server_side) as Box<dyn Transport>),
    ];
    let started = std::time::Instant::now();
    let res = serve(links, &ds, &cfg).unwrap();
    real.join().unwrap().unwrap();
    spam.join().unwrap();

    assert_eq!(res.comm.rounds, 5);
    assert_eq!(res.live_workers, 1, "spammer must be dropped");
    assert!(
        started.elapsed() < cfg.round_timeout,
        "drop took a full deadline — the heartbeat cap did not fire"
    );
}

#[test]
fn worker_missing_layer_capability_is_refused_at_handshake() {
    // lenet5 requires the "conv" capability; a worker that advertises
    // none must be refused with a Shutdown reason DURING the handshake
    // — never admitted to fail mid-round with an executor error.
    let spec = DataSpec::new("digits", 64, 256, 5);
    let ds = spec.build();
    let mut c = cfg(1, 1, &spec);
    c.model = "lenet5".into();

    let (server_side, mut bare) = ChannelTransport::pair("bare");
    let worker = std::thread::spawn(move || {
        bare.send(&Msg::Hello {
            proto: ditherprop::net::PROTO_VERSION,
            platform: "bare-mlp-backend".into(),
            features: vec![], // no conv/batchnorm/residual
        })
        .unwrap();
        match bare.recv().unwrap() {
            Msg::Shutdown { reason } => {
                assert!(reason.contains("conv"), "refusal must name the gap: {reason}");
                assert!(reason.contains("lenet5"), "refusal must name the model: {reason}");
            }
            other => panic!("expected a Shutdown refusal, got tag {}", other.tag()),
        }
    });

    let links = vec![Some(Box::new(server_side) as Box<dyn Transport>)];
    let err = serve(links, &ds, &c).unwrap_err();
    assert!(
        err.to_string().contains("conv"),
        "server error must surface the capability gap: {err}"
    );
    worker.join().unwrap();
}

#[test]
fn silent_worker_is_dropped_as_straggler() {
    let spec = DataSpec::new("digits", 256, 256, 5);
    let ds = spec.build();
    let mut cfg = cfg(2, 8, &spec);
    cfg.round_timeout = Duration::from_millis(400);

    // worker 0: real; worker 1: handshakes, then goes silent forever
    let (real_server_side, real_worker_side) = ChannelTransport::pair("real");
    let shard = ds.train.shard(0, 2);
    let real = std::thread::spawn(move || {
        worker_loop(Box::new(real_worker_side), &artifacts(), Some(shard))
    });

    let (mute_server_side, mut mute_worker_side) = ChannelTransport::pair("mute");
    let mute = std::thread::spawn(move || {
        mute_worker_side
            .send(&Msg::Hello {
                proto: ditherprop::net::PROTO_VERSION,
                platform: "mute".into(),
                features: vec![],
            })
            .unwrap();
        // swallow the Welcome + params, never answer, outlive the run
        while mute_worker_side.recv().is_ok() {}
    });

    let links = vec![
        Some(Box::new(real_server_side) as Box<dyn Transport>),
        Some(Box::new(mute_server_side) as Box<dyn Transport>),
    ];
    let res = serve(links, &ds, &cfg).unwrap();
    real.join().unwrap().unwrap();
    mute.join().unwrap();

    assert_eq!(res.comm.rounds, 8, "run must complete despite the straggler");
    assert_eq!(res.live_workers, 1, "straggler must be dropped");
    // the mute link's handshake bytes still show up in the accounting
    assert!(res.comm.wire_up_bytes > 0);
}
