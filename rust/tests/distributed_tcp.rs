//! Integration: the distributed coordinator over real TCP sockets.
//!
//! Three claims pinned here:
//!  1. a server + 2 worker *processes-worth* of protocol over
//!     127.0.0.1 ephemeral ports trains end to end (loss decreases)
//!     and moves fewer measured bytes than dense gradients would,
//!  2. a channel-transport run and a TCP-loopback run with the same
//!     seeds produce bit-identical parameter vectors (the transport is
//!     semantically invisible),
//!  3. a worker that goes silent is dropped as a straggler and the run
//!     completes with the survivors.

use ditherprop::coordinator::comm::EncodedGrads;
use ditherprop::coordinator::{
    run_distributed, run_distributed_async, serve, serve_tcp, worker_loop, AsyncCfg, DistConfig,
};
use ditherprop::data::DataSpec;
use ditherprop::net::{ChannelTransport, Msg, TcpTransport, Transport};
use ditherprop::optim::{LrSchedule, SgdConfig};
use ditherprop::tensor::Tensor;
use std::net::TcpListener;
use std::time::Duration;

/// A directory that never hosts AOT artifacts, so every engine load
/// serves the built-in native zoo.
fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/native-zoo").to_string()
}

fn cfg(nodes: usize, rounds: usize, spec: &DataSpec) -> DistConfig {
    DistConfig {
        artifacts_dir: artifacts(),
        model: "mlp128".into(),
        method: "dithered".into(),
        s: 3.0,
        nodes,
        rounds,
        opt: SgdConfig { lr: LrSchedule::constant(0.02), momentum: 0.9, weight_decay: 5e-4 },
        seed: 9,
        verbose: false,
        data: Some(spec.clone()),
        round_timeout: Duration::from_secs(20),
        async_cfg: None,
    }
}

/// Transport wrapper that swallows gradient uploads — a worker that
/// stays connected and acks rounds but never delivers work, i.e. the
/// worst kind of straggler.
struct MuteUploads<T: Transport>(T);

impl<T: Transport> Transport for MuteUploads<T> {
    fn send(&mut self, msg: &Msg) -> anyhow::Result<()> {
        if matches!(msg, Msg::Grads { .. }) {
            return Ok(()); // the server never sees the upload
        }
        self.0.send(msg)
    }
    fn recv(&mut self) -> anyhow::Result<Msg> {
        self.0.recv()
    }
    fn recv_deadline(&mut self, timeout: Duration) -> anyhow::Result<Option<Msg>> {
        self.0.recv_deadline(timeout)
    }
    fn bytes_sent(&self) -> u64 {
        self.0.bytes_sent()
    }
    fn bytes_received(&self) -> u64 {
        self.0.bytes_received()
    }
    fn peer(&self) -> String {
        self.0.peer()
    }
}

/// Transport wrapper that sleeps before every send — slows a worker's
/// step rate without violating any protocol rule.
struct Throttled<T: Transport>(T, Duration);

impl<T: Transport> Transport for Throttled<T> {
    fn send(&mut self, msg: &Msg) -> anyhow::Result<()> {
        std::thread::sleep(self.1);
        self.0.send(msg)
    }
    fn recv(&mut self) -> anyhow::Result<Msg> {
        self.0.recv()
    }
    fn recv_deadline(&mut self, timeout: Duration) -> anyhow::Result<Option<Msg>> {
        self.0.recv_deadline(timeout)
    }
    fn bytes_sent(&self) -> u64 {
        self.0.bytes_sent()
    }
    fn bytes_received(&self) -> u64 {
        self.0.bytes_received()
    }
    fn peer(&self) -> String {
        self.0.peer()
    }
}

/// Spawn `n` worker threads that connect to `addr` over real TCP and
/// regenerate their shards from the Welcome's DataSpec — exactly what
/// `dist-worker` processes do, minus the fork/exec.
fn spawn_tcp_workers(
    addr: std::net::SocketAddr,
    n: usize,
) -> Vec<std::thread::JoinHandle<anyhow::Result<()>>> {
    (0..n)
        .map(|_| {
            std::thread::spawn(move || {
                let link = TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10))?;
                worker_loop(Box::new(link), &artifacts(), None)
            })
        })
        .collect()
}

#[test]
fn tcp_loopback_two_workers_learn_and_compress() {
    let spec = DataSpec::new("digits", 512, 512, 6);
    let ds = spec.build();
    let cfg = cfg(2, 60, &spec);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_tcp_workers(addr, 2);
    let res = serve_tcp(&listener, &ds, &cfg).unwrap();
    for w in workers {
        w.join().unwrap().unwrap();
    }

    assert_eq!(res.comm.rounds, 60);
    assert_eq!(res.live_workers, 2);
    // learning: early-round loss above late-round loss
    let first = res.history.steps[..15].iter().map(|r| r.loss).sum::<f32>() / 15.0;
    let last = res.history.steps[45..].iter().map(|r| r.loss).sum::<f32>() / 15.0;
    assert!(last < first, "TCP loss not decreasing: {first} -> {last}");
    assert!(res.mean_sparsity > 0.5, "sparsity {}", res.mean_sparsity);
    // measured wire bytes (framing, handshake and heartbeats included)
    // must beat shipping dense f32 gradients
    assert!(res.comm.wire_up_bytes > 0, "byte counters never absorbed");
    assert!(
        res.comm.wire_up_bytes < res.comm.up_bytes_dense as u64,
        "measured {} wire bytes >= {} dense bytes",
        res.comm.wire_up_bytes,
        res.comm.up_bytes_dense
    );
    assert!(
        res.comm.measured_up_savings() > 1.5,
        "measured savings only x{:.2}",
        res.comm.measured_up_savings()
    );
}

#[test]
fn channel_and_tcp_runs_are_bit_identical() {
    let spec = DataSpec::new("digits", 384, 256, 11);
    let ds = spec.build();
    let cfg = cfg(2, 25, &spec);

    // channel-transport run (single process, worker threads)
    let chan = run_distributed(&ds, &cfg).unwrap();

    // TCP-loopback run, same seeds/config
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_tcp_workers(addr, 2);
    let tcp = serve_tcp(&listener, &ds, &cfg).unwrap();
    for w in workers {
        w.join().unwrap().unwrap();
    }

    assert_eq!(
        chan.params, tcp.params,
        "channel vs TCP parameter vectors diverged after {} rounds",
        cfg.rounds
    );
    assert_eq!(chan.test_acc, tcp.test_acc);
    assert_eq!(chan.comm.up_bytes, tcp.comm.up_bytes, "analytic codec bytes must match");
    // per-round losses identical too (same examples, same dither)
    for (a, b) in chan.history.steps.iter().zip(tcp.history.steps.iter()) {
        assert_eq!(a.loss, b.loss, "loss diverged at round {}", a.step);
    }
}

#[test]
fn heartbeat_spammer_is_dropped_not_waited_on() {
    // A peer that keeps acking but never uploads must not be able to
    // wedge the gather loop by resetting its deadline forever: the
    // second heartbeat in one round is a protocol violation and drops
    // the worker immediately (no timeout wait — keep round_timeout
    // large to prove the drop is cap-driven, not deadline-driven).
    let spec = DataSpec::new("digits", 256, 256, 5);
    let ds = spec.build();
    let mut cfg = cfg(2, 5, &spec);
    cfg.round_timeout = Duration::from_secs(30);

    let (real_server_side, real_worker_side) = ChannelTransport::pair("real");
    let shard = ds.train.shard(0, 2);
    let real = std::thread::spawn(move || {
        worker_loop(Box::new(real_worker_side), &artifacts(), Some(shard))
    });

    let (spam_server_side, mut spam_link) = ChannelTransport::pair("spam");
    let spam = std::thread::spawn(move || {
        spam_link
            .send(&Msg::Hello {
                proto: ditherprop::net::PROTO_VERSION,
                platform: "spam".into(),
                features: vec![],
            })
            .unwrap();
        let node = match spam_link.recv().unwrap() {
            Msg::Welcome(w) => w.node,
            other => panic!("expected Welcome, got tag {}", other.tag()),
        };
        loop {
            match spam_link.recv() {
                Ok(Msg::Params { round, .. }) => {
                    for _ in 0..5 {
                        if spam_link.send(&Msg::Heartbeat { node, round }).is_err() {
                            return; // dropped by the server, as expected
                        }
                    }
                }
                Ok(_) => {}
                Err(_) => return,
            }
        }
    });

    let links = vec![
        Some(Box::new(real_server_side) as Box<dyn Transport>),
        Some(Box::new(spam_server_side) as Box<dyn Transport>),
    ];
    let started = std::time::Instant::now();
    let res = serve(links, &ds, &cfg).unwrap();
    real.join().unwrap().unwrap();
    spam.join().unwrap();

    assert_eq!(res.comm.rounds, 5);
    assert_eq!(res.live_workers, 1, "spammer must be dropped");
    assert!(
        started.elapsed() < cfg.round_timeout,
        "drop took a full deadline — the heartbeat cap did not fire"
    );
}

#[test]
fn worker_missing_layer_capability_is_refused_at_handshake() {
    // lenet5 requires the "conv" capability; a worker that advertises
    // none must be refused with a Shutdown reason DURING the handshake
    // — never admitted to fail mid-round with an executor error.
    let spec = DataSpec::new("digits", 64, 256, 5);
    let ds = spec.build();
    let mut c = cfg(1, 1, &spec);
    c.model = "lenet5".into();

    let (server_side, mut bare) = ChannelTransport::pair("bare");
    let worker = std::thread::spawn(move || {
        bare.send(&Msg::Hello {
            proto: ditherprop::net::PROTO_VERSION,
            platform: "bare-mlp-backend".into(),
            features: vec![], // no conv/batchnorm/residual
        })
        .unwrap();
        match bare.recv().unwrap() {
            Msg::Shutdown { reason, .. } => {
                assert!(reason.contains("conv"), "refusal must name the gap: {reason}");
                assert!(reason.contains("lenet5"), "refusal must name the model: {reason}");
            }
            other => panic!("expected a Shutdown refusal, got tag {}", other.tag()),
        }
    });

    let links = vec![Some(Box::new(server_side) as Box<dyn Transport>)];
    let err = serve(links, &ds, &c).unwrap_err();
    assert!(
        err.to_string().contains("conv"),
        "server error must surface the capability gap: {err}"
    );
    worker.join().unwrap();
}

#[test]
fn silent_worker_is_dropped_as_straggler() {
    let spec = DataSpec::new("digits", 256, 256, 5);
    let ds = spec.build();
    let mut cfg = cfg(2, 8, &spec);
    cfg.round_timeout = Duration::from_millis(400);

    // worker 0: real; worker 1: handshakes, then goes silent forever
    let (real_server_side, real_worker_side) = ChannelTransport::pair("real");
    let shard = ds.train.shard(0, 2);
    let real = std::thread::spawn(move || {
        worker_loop(Box::new(real_worker_side), &artifacts(), Some(shard))
    });

    let (mute_server_side, mut mute_worker_side) = ChannelTransport::pair("mute");
    let mute = std::thread::spawn(move || {
        mute_worker_side
            .send(&Msg::Hello {
                proto: ditherprop::net::PROTO_VERSION,
                platform: "mute".into(),
                features: vec![],
            })
            .unwrap();
        // swallow the Welcome + params, never answer, outlive the run
        while mute_worker_side.recv().is_ok() {}
    });

    let links = vec![
        Some(Box::new(real_server_side) as Box<dyn Transport>),
        Some(Box::new(mute_server_side) as Box<dyn Transport>),
    ];
    let res = serve(links, &ds, &cfg).unwrap();
    real.join().unwrap().unwrap();
    mute.join().unwrap();

    assert_eq!(res.comm.rounds, 8, "run must complete despite the straggler");
    assert_eq!(res.live_workers, 1, "straggler must be dropped");
    // the mute link's handshake bytes still show up in the accounting
    assert!(res.comm.wire_up_bytes > 0);
}

#[test]
fn dropped_tcp_worker_exits_fast_with_the_servers_reason() {
    // A worker dropped as a straggler must terminate promptly with the
    // server's reason in its error — NOT block until its own
    // SERVER_SILENCE_TIMEOUT (120s) expires against a retired link.
    let spec = DataSpec::new("digits", 256, 256, 5);
    let ds = spec.build();
    let mut cfg = cfg(2, 6, &spec);
    cfg.round_timeout = Duration::from_millis(500);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // worker A: honest
    let honest = std::thread::spawn(move || {
        let link = TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10))?;
        worker_loop(Box::new(link), &artifacts(), None)
    });
    // worker B: a full worker_loop whose uploads vanish in transit
    let muted = std::thread::spawn(move || {
        let started = std::time::Instant::now();
        let link = TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10))
            .expect("connect");
        let res = worker_loop(Box::new(MuteUploads(link)), &artifacts(), None);
        (started.elapsed(), res)
    });

    let res = serve_tcp(&listener, &ds, &cfg).unwrap();
    honest.join().unwrap().unwrap();
    let (elapsed, muted_res) = muted.join().unwrap();

    assert_eq!(res.comm.rounds, 6, "run must complete with the survivor");
    assert_eq!(res.live_workers, 1);
    let err = muted_res.expect_err("the muted worker must exit with an error");
    let msg = format!("{err:#}");
    assert!(msg.contains("server dropped this worker"), "{msg}");
    assert!(msg.contains("straggler"), "reason must name the drop cause: {msg}");
    assert!(
        elapsed < Duration::from_secs(5),
        "dropped worker took {elapsed:?} to exit — the reasoned Shutdown did not reach it"
    );
}

#[test]
fn handshake_failure_notifies_already_admitted_workers() {
    // When worker k fails the handshake, workers 0..k have already been
    // Welcomed and are blocking on their first Params.  The server must
    // broadcast the abort before bailing, or they hang out their full
    // silence timeout.
    let spec = DataSpec::new("digits", 128, 256, 5);
    let ds = spec.build();
    let c = cfg(2, 3, &spec);

    // worker 0: a real worker_loop — gets Welcomed, then must be told
    let (w0_server, w0_link) = ChannelTransport::pair("w0");
    let shard = ds.train.shard(0, 2);
    let w0 = std::thread::spawn(move || {
        let started = std::time::Instant::now();
        (started.elapsed(), worker_loop(Box::new(w0_link), &artifacts(), Some(shard)))
    });
    // worker 1: violates the handshake (Heartbeat instead of Hello)
    let (w1_server, mut w1_link) = ChannelTransport::pair("w1");
    let w1 = std::thread::spawn(move || {
        w1_link.send(&Msg::Heartbeat { node: 9, round: 0 }).unwrap();
        // the refusal must come back as a fault Shutdown
        match w1_link.recv().unwrap() {
            Msg::Shutdown { fault, reason } => {
                assert!(fault, "a handshake refusal is a fault");
                assert!(reason.contains("instead of Hello"), "{reason}");
            }
            other => panic!("expected Shutdown, got tag {}", other.tag()),
        }
    });

    let links = vec![
        Some(Box::new(w0_server) as Box<dyn Transport>),
        Some(Box::new(w1_server) as Box<dyn Transport>),
    ];
    let err = serve(links, &ds, &c).unwrap_err();
    assert!(err.to_string().contains("worker 1 failed the handshake"), "{err}");

    let (elapsed, w0_res) = w0.join().unwrap();
    w1.join().unwrap();
    let msg = format!("{:#}", w0_res.expect_err("w0 must be told the launch died"));
    assert!(msg.contains("aborting launch"), "{msg}");
    assert!(msg.contains("worker 1 failed the handshake"), "{msg}");
    assert!(
        elapsed < Duration::from_secs(5),
        "admitted worker took {elapsed:?} to learn the launch died"
    );
}

#[test]
fn async_channel_run_respects_the_staleness_bound() {
    let spec = DataSpec::new("digits", 384, 256, 11);
    let ds = spec.build();
    let mut c = cfg(2, 80, &spec);
    c.async_cfg = Some(AsyncCfg { shards: 3, max_staleness: 5 });

    let res = run_distributed_async(&ds, &c).unwrap();

    assert_eq!(res.comm.rounds, 80, "async run must complete its step target");
    assert_eq!(res.history.steps.len(), 80);
    assert_eq!(res.live_workers, 2, "both workers should survive a clean run");
    let stats = res.async_stats.expect("async run must report async stats");
    assert!(stats.applied > 0, "no uploads were ever applied");
    assert!(
        stats.bound_respected(5),
        "staleness bound violated: max {} hist {:?} applied {}",
        stats.max_applied_staleness,
        stats.staleness_hist,
        stats.applied
    );
    assert_eq!(stats.joined, 0, "channel mode has no elastic joins");
    // learning still happens through the async path
    let first = res.history.steps[..20].iter().map(|r| r.loss).sum::<f32>() / 20.0;
    let last = res.history.steps[60..].iter().map(|r| r.loss).sum::<f32>() / 20.0;
    assert!(last < first, "async loss not decreasing: {first} -> {last}");
    // measured wire accounting flows through the async path too
    assert!(res.comm.wire_up_bytes > 0);
    assert!(res.comm.up_bytes > 0);
}

#[test]
fn elastic_membership_joins_and_leaves_mid_run() {
    // 2 workers accepted at launch; one leaves after a few steps; a
    // third dials in mid-run and is admitted through the same Hello
    // handshake.  The run completes, the staleness bound holds, and
    // the membership counters record the churn.
    let spec = DataSpec::new("digits", 256, 256, 7);
    let ds = spec.build();
    let mut c = cfg(2, 100, &spec);
    c.async_cfg = Some(AsyncCfg { shards: 2, max_staleness: 6 });

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    // worker A: honest but throttled, so the run outlasts the churn below
    let a = std::thread::spawn(move || {
        let link = TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10))?;
        worker_loop(Box::new(Throttled(link, Duration::from_millis(3))), &artifacts(), None)
    });
    // worker B: scripted async peer — 10 zero-gradient steps, then leaves
    let b = std::thread::spawn(move || {
        let mut link =
            TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
        link.send(&Msg::Hello {
            proto: ditherprop::net::PROTO_VERSION,
            platform: "scripted".into(),
            features: vec![],
        })
        .unwrap();
        let job = match link.recv().unwrap() {
            Msg::Welcome(w) => w.async_job.expect("async server must describe the job"),
            other => panic!("expected Welcome, got tag {}", other.tag()),
        };
        assert_eq!(job.shards, 2, "mlp128 has >= 2 tensors, shards stay at 2");
        for _ in 0..10 {
            for sh in 0..job.shards {
                link.send(&Msg::PullParams { node: 99, shard: sh }).unwrap();
            }
            for _ in 0..job.shards {
                match link.recv().unwrap() {
                    Msg::ShardParams { shard, version, tensors } => {
                        let flat: Vec<Tensor> = tensors
                            .iter()
                            .map(|v| Tensor::from_vec(&[v.len()], vec![0.0; v.len()]))
                            .collect();
                        let grads = EncodedGrads::encode(&flat, 2.3, 0.0, vec![1.0], vec![0.0]);
                        link.send(&Msg::PushGrads { node: 99, shard, version, grads }).unwrap();
                    }
                    Msg::Shutdown { .. } => return, // run ended under us
                    other => panic!("expected ShardParams, got tag {}", other.tag()),
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // leave without a word: the server must absorb the dead link
    });

    // worker C: honest, dials in mid-run
    let c_handle = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(200));
        let link = TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10))?;
        worker_loop(Box::new(link), &artifacts(), None)
    });

    let res = serve_tcp(&listener, &ds, &c).unwrap();
    a.join().unwrap().unwrap();
    b.join().unwrap();
    c_handle.join().unwrap().unwrap();

    assert_eq!(res.comm.rounds, 100, "elastic run must complete its step target");
    let stats = res.async_stats.expect("async run must report async stats");
    assert!(stats.joined >= 1, "the mid-run joiner was never admitted");
    assert!(stats.left >= 1, "the departed worker was never noticed");
    assert!(
        stats.bound_respected(6),
        "staleness bound violated: max {} hist {:?}",
        stats.max_applied_staleness,
        stats.staleness_hist
    );
    assert!(stats.applied > 0);
    assert_eq!(res.live_workers, 2, "A and C should be live at the end");
}
