//! Integration: experiment harnesses at smoke scale + render contracts
//! (running on whichever backend `Engine::load` selects — the native
//! executor on a bare checkout).

use ditherprop::experiments::{eq12, fig1, fig2, fig4, table1, Scale};
use ditherprop::util::cli::Args;

fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[test]
fn fig1_harvests_real_delta_z() {
    let data = fig1::collect(&artifacts(), "mlp500", 2.0, 8).unwrap();
    assert_eq!(data.before.len(), data.after.len());
    assert!(!data.before.is_empty());
    let hb = fig1::histogram(&data.before, 21);
    let ha = fig1::histogram(&data.after, 21);
    // NSD must raise the zero fraction on real delta_z
    assert!(
        ha.zero_fraction > hb.zero_fraction + 0.1,
        "before {} after {}",
        hb.zero_fraction,
        ha.zero_fraction
    );
    // render smoke
    let txt = fig1::render(&data, 21);
    assert!(txt.contains("after NSD"));
}

#[test]
fn fig2_rows_render_and_agree() {
    let rows = fig2::run(&[1.0, 4.0], 50_000);
    let txt = fig2::render(&rows);
    assert!(txt.contains("P0 analytic"));
    for r in rows {
        assert!((r.analytic - r.host_nsd).abs() < 0.03);
    }
}

#[test]
fn eq12_render_includes_all_cells() {
    let rows = eq12::run(&[16, 256], &[0.5, 0.05], 1);
    assert_eq!(rows.len(), 4);
    let txt = eq12::render(&rows);
    assert!(txt.matches('\n').count() >= 6);
}

#[test]
fn table1_lenet5_conv_row_smoke() {
    // The conv rows of Table 1 run natively now: a few-step lenet5 run
    // on synth digits must learn (loss decreases) and the dithered
    // backward must report substantial delta_z sparsity.
    let scale = Scale { steps: 16, rounds: 1, n_train: 512, n_test: 256, reps: 1 };
    let cells =
        table1::run(&artifacts(), &["lenet5".to_string()], scale, false).unwrap();
    assert_eq!(cells.len(), 4); // baseline, dithered, int8, int8_dithered
    for c in &cells {
        assert_eq!(c.dataset, "digits");
        assert!(
            c.loss_end < c.loss_start,
            "{}: loss did not decrease ({} -> {})",
            c.method,
            c.loss_start,
            c.loss_end
        );
    }
    let dith = cells.iter().find(|c| c.method == "dithered").unwrap();
    let base = cells.iter().find(|c| c.method == "baseline").unwrap();
    assert!(
        dith.sparsity > 0.5,
        "dithered backward sparsity only {:.3}",
        dith.sparsity
    );
    assert!(dith.sparsity > base.sparsity, "dithered must beat baseline sparsity");
    // per-layer sparsity covers all 5 weighted lenet5 layers (conv1,
    // conv2, fc1, fc2, fc3) and every layer got quantized
    assert_eq!(dith.layer_sparsity.len(), 5);
    assert!(
        dith.layer_sparsity.iter().all(|&s| s > 0.0),
        "per-layer sparsity has zeros: {:?}",
        dith.layer_sparsity
    );
}

#[test]
fn table1_vgg8bn_with_bn_row_smoke() {
    // The paper's with-BN rows run natively now: a few-step vgg8bn run
    // (6 conv+BN stages + 2 dense) must learn under every table method
    // and the dithered backward must report per-layer sparsity for all
    // 8 weighted layers — BN re-densifies the deltas in between, so
    // high sparsity here proves the per-layer re-quantization works.
    let scale = Scale { steps: 16, rounds: 1, n_train: 384, n_test: 256, reps: 1 };
    let cells =
        table1::run(&artifacts(), &["vgg8bn".to_string()], scale, false).unwrap();
    assert_eq!(cells.len(), 4); // baseline, dithered, int8, int8_dithered
    for c in &cells {
        assert_eq!(c.dataset, "textures");
        assert!(
            c.loss_end < c.loss_start,
            "{}: loss did not decrease ({} -> {})",
            c.method,
            c.loss_start,
            c.loss_end
        );
    }
    let dith = cells.iter().find(|c| c.method == "dithered").unwrap();
    let base = cells.iter().find(|c| c.method == "baseline").unwrap();
    assert!(
        dith.sparsity > 0.5,
        "dithered backward sparsity only {:.3}",
        dith.sparsity
    );
    assert!(dith.sparsity > base.sparsity, "dithered must beat baseline sparsity");
    // per-layer sparsity covers all 8 weighted vgg8bn layers (6 conv +
    // fc1 + fc2) and every layer got quantized
    assert_eq!(dith.layer_sparsity.len(), 8);
    assert!(
        dith.layer_sparsity.iter().all(|&s| s > 0.0),
        "per-layer sparsity has zeros: {:?}",
        dith.layer_sparsity
    );
}

#[test]
fn table1_render_averages_and_headline() {
    let mk = |model: &str, method: &str, acc: f32, sp: f32| table1::Cell {
        model: model.into(),
        dataset: "digits".into(),
        method: method.into(),
        acc,
        sparsity: sp,
        layer_sparsity: vec![sp, sp],
        max_bits: 6,
        loss_start: 2.3,
        loss_end: 0.4,
    };
    let mut cells = Vec::new();
    for m in ["a", "b"] {
        cells.push(mk(m, "baseline", 0.9, 0.3));
        cells.push(mk(m, "dithered", 0.9, 0.9));
        cells.push(mk(m, "int8", 0.9, 0.35));
        cells.push(mk(m, "int8_dithered", 0.9, 0.92));
    }
    let txt = table1::render(&cells);
    assert!(txt.contains("Average"));
    assert!(txt.contains("sparsity boost (dithered - baseline): +60.0%"));
    assert!(txt.contains("projected SCNN gains"));
}

#[test]
fn fig4_render_headline_logic() {
    let p = |label: &str, sp: f32, acc: f32| fig4::SweepPoint {
        label: label.into(),
        sparsity: sp,
        acc_mean: acc,
        acc_std: 0.01,
    };
    let pts = vec![
        p("baseline", 0.3, 0.99),
        p("dithered s=4", 0.9, 0.985),
        p("meprop_k5", 0.95, 0.97),
    ];
    let txt = fig4::render(&pts);
    assert!(txt.contains("headline: dithered 98.50% acc"));
    assert!(txt.contains("meProp 97.00%"));
}

#[test]
fn scale_parsing_from_cli() {
    let args = Args::parse(
        "x --quick --steps 42".split_whitespace().map(String::from),
    );
    let s = ditherprop::experiments::Scale::from_args(&args);
    assert_eq!(s.steps, 42); // override wins over quick default
    assert_eq!(s.reps, 1); // quick default
}
