//! Integration tests over the runtime with the default (native)
//! backend: the same paper invariants the AOT artifacts were tested
//! against, now exercised on a bare checkout with no artifacts at all.

use ditherprop::data;
use ditherprop::runtime::Engine;
use ditherprop::train::step_seed;

fn engine() -> Engine {
    Engine::native().expect("built-in native registry must load")
}

#[test]
fn load_of_missing_dir_serves_native_zoo() {
    let e = Engine::load(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/native-zoo")).unwrap();
    assert_eq!(e.platform(), "native-cpu");
    assert!(e.manifest.model("mlp500").is_ok());
}

#[test]
fn manifest_lists_all_native_models() {
    let e = engine();
    for m in ["lenet300100", "mlp500", "mlp128", "mlptex", "lenet5", "minivgg"] {
        let entry = e.manifest.model(m).unwrap();
        assert!(entry.n_params() >= 4);
        assert!(entry.total_weights() > 10_000);
        assert!(entry.methods().contains(&"dithered".to_string()));
    }
    assert!(e.manifest.model("nope").is_err());
}

#[test]
fn conv_model_runs_on_textures() {
    // minivgg end to end: two conv blocks on 16x16x3 NHWC inputs,
    // dithered backward with per-layer stats for all 6 weighted layers.
    let e = engine();
    let sess = e.training_session("minivgg", "dithered", 4).unwrap();
    let params = e.init_params("minivgg", 0).unwrap();
    assert_eq!(params.len(), 12);
    assert_eq!(params[0].shape(), &[3, 3, 3, 16]); // conv1_w, HWIO
    let ds = data::build("textures", 16, 16, 13);
    let mut it = data::BatchIter::new(&ds.train, 4, 8);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 3, 2.0).unwrap();
    assert_eq!(out.grads.len(), 12);
    assert_eq!(out.sparsity.len(), 6);
    assert!(out.loss > 1.5 && out.loss < 4.0, "fresh-init CE loss ~ln(10), got {}", out.loss);
    assert!(out.mean_sparsity() > 0.3, "dithered conv sparsity {:?}", out.sparsity);
    // every weight gradient received signal
    assert!(out.grads.iter().step_by(2).all(|g| g.abs_max() > 0.0));
}

#[test]
fn init_params_match_manifest_shapes_and_are_reproducible() {
    let e = engine();
    let p1 = e.init_params("mlp500", 7).unwrap();
    let p2 = e.init_params("mlp500", 7).unwrap();
    let p3 = e.init_params("mlp500", 8).unwrap();
    let entry = e.manifest.model("mlp500").unwrap();
    for (t, info) in p1.iter().zip(entry.params.iter()) {
        assert_eq!(t.shape(), &info.shape[..]);
    }
    for (a, b) in p1.iter().zip(p2.iter()) {
        assert_eq!(a.data(), b.data(), "init not deterministic");
    }
    assert!(p1.iter().zip(p3.iter()).any(|(a, b)| a.data() != b.data()));
    // weights nonzero, biases zero
    assert!(p1[0].abs_max() > 0.0);
    assert_eq!(p1[1].abs_max(), 0.0);
}

#[test]
fn grad_step_shapes_losses_and_stats() {
    let e = engine();
    let sess = e.training_session("mlp500", "dithered", 64).unwrap();
    let params = e.init_params("mlp500", 0).unwrap();
    let ds = data::build("digits", 256, 64, 5);
    let mut it = data::BatchIter::new(&ds.train, 64, 1);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 9, 2.0).unwrap();
    assert_eq!(out.grads.len(), 6);
    assert!(out.loss > 1.5 && out.loss < 4.0, "fresh-init CE loss ~ln(10), got {}", out.loss);
    assert!(out.correct >= 0.0 && out.correct <= 64.0);
    assert_eq!(out.sparsity.len(), 3);
    assert_eq!(out.max_level.len(), 3);
    assert!(out.mean_sparsity() > 0.5, "dithered sparsity too low: {:?}", out.sparsity);
    assert!(out.max_bitwidth() <= 8, "bits {} > 8", out.max_bitwidth());
}

#[test]
fn dithered_s0_matches_baseline_grads() {
    let e = engine();
    let db = e.training_session("mlp500", "baseline", 64).unwrap();
    let dd = e.training_session("mlp500", "dithered", 64).unwrap();
    let params = e.init_params("mlp500", 1).unwrap();
    let ds = data::build("digits", 128, 64, 6);
    let mut it = data::BatchIter::new(&ds.train, 64, 2);
    it.next_batch(&ds.train);
    let gb = db.grad(&params, &it.x, &it.y, 3, 0.0).unwrap();
    let gd = dd.grad(&params, &it.x, &it.y, 3, 0.0).unwrap();
    for (a, b) in gb.grads.iter().zip(gd.grads.iter()) {
        assert_eq!(a.data(), b.data(), "s=0 dithered must equal baseline exactly");
    }
}

#[test]
fn dither_seed_changes_grads_baseline_ignores_it() {
    let e = engine();
    let sess = e.training_session("mlp500", "dithered", 64).unwrap();
    let base = e.training_session("mlp500", "baseline", 64).unwrap();
    let params = e.init_params("mlp500", 2).unwrap();
    let ds = data::build("digits", 128, 64, 7);
    let mut it = data::BatchIter::new(&ds.train, 64, 3);
    it.next_batch(&ds.train);
    let g1 = sess.grad(&params, &it.x, &it.y, 1, 2.0).unwrap();
    let g2 = sess.grad(&params, &it.x, &it.y, 2, 2.0).unwrap();
    assert!(g1.grads[0].data() != g2.grads[0].data(), "seed had no effect");
    let b1 = base.grad(&params, &it.x, &it.y, 1, 2.0).unwrap();
    let b2 = base.grad(&params, &it.x, &it.y, 2, 2.0).unwrap();
    assert_eq!(b1.grads[0].data(), b2.grads[0].data(), "baseline must be seed-independent");
}

#[test]
fn sparsity_grows_with_s() {
    let e = engine();
    let sess = e.training_session("mlp500", "dithered", 64).unwrap();
    let params = e.init_params("mlp500", 3).unwrap();
    let ds = data::build("digits", 128, 64, 8);
    let mut it = data::BatchIter::new(&ds.train, 64, 4);
    it.next_batch(&ds.train);
    let mut prev = 0.0;
    for s in [0.5f32, 1.0, 2.0, 4.0, 8.0] {
        let out = sess.grad(&params, &it.x, &it.y, 11, s).unwrap();
        let sp = out.mean_sparsity();
        assert!(sp >= prev - 0.03, "sparsity not monotone at s={s}: {sp} < {prev}");
        prev = sp;
    }
    assert!(prev > 0.85, "s=8 sparsity only {prev}");
}

#[test]
fn eval_counts_correct_predictions() {
    let e = engine();
    let sess = e.training_session("lenet300100", "baseline", 64).unwrap();
    let params = e.init_params("lenet300100", 4).unwrap();
    let ds = data::build("digits", 512, 256, 9);
    let out = sess
        .eval_dataset(&params, &ds.test.images, &ds.test.labels)
        .unwrap();
    // fresh init: accuracy near chance (10%), loss near ln(10)
    let acc = out.correct / 256.0;
    assert!(acc < 0.4, "untrained acc suspiciously high: {acc}");
    assert!(out.loss > 1.5 && out.loss < 4.0);
}

#[test]
fn meprop_rows_are_sparse() {
    let e = engine();
    let sess = e.training_session("mlp500", "meprop_k25", 64).unwrap();
    let params = e.init_params("mlp500", 5).unwrap();
    let ds = data::build("digits", 128, 64, 10);
    let mut it = data::BatchIter::new(&ds.train, 64, 5);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 1, 0.0).unwrap();
    // hidden 500 keep 25 -> 95% sparsity on hidden layers
    assert!(out.sparsity[0] > 0.9 && out.sparsity[1] > 0.9, "{:?}", out.sparsity);
}

#[test]
fn int8_methods_produce_full_level_range() {
    let e = engine();
    let sess = e.training_session("mlp128", "int8", 32).unwrap();
    let params = e.init_params("mlp128", 6).unwrap();
    let ds = data::build("digits", 64, 64, 11);
    let mut it = data::BatchIter::new(&ds.train, 32, 6);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 1, 0.0).unwrap();
    assert_eq!(out.max_bitwidth(), 8, "int8 worst-case bits: {:?}", out.max_level);
}

#[test]
fn textures_model_runs() {
    let e = engine();
    let sess = e.training_session("mlptex", "dithered", 16).unwrap();
    let params = e.init_params("mlptex", 0).unwrap();
    let ds = data::build("textures", 64, 64, 12);
    let mut it = data::BatchIter::new(&ds.train, 16, 7);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 3, 2.0).unwrap();
    assert_eq!(out.grads.len(), 4);
    assert!(out.mean_sparsity() > 0.3);
}

#[test]
fn step_seed_is_stable_contract() {
    // rust-side seeds feed the dither streams; pin the function so runs
    // are reproducible across refactors
    assert_eq!(step_seed(42, 0), step_seed(42, 0));
    assert_ne!(step_seed(42, 0), step_seed(42, 1));
    assert_ne!(step_seed(42, 0), step_seed(43, 0));
}
