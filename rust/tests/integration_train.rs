//! Integration: full training runs through the real stack — the
//! convergence claims of the paper at smoke scale, plus the distributed
//! coordinator end to end.

use ditherprop::coordinator::{run_distributed, DistConfig};
use ditherprop::data;
use ditherprop::optim::{LrSchedule, SgdConfig};
use ditherprop::runtime::Engine;
use ditherprop::train::{train, TrainConfig};

fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[test]
fn dithered_training_learns_and_stays_sparse() {
    let engine = Engine::load(artifacts()).unwrap();
    let ds = data::build("digits", 1024, 512, 3);
    let cfg = TrainConfig::quick("mlp500", "dithered", 2.0, 60);
    let res = train(&engine, &ds, &cfg).unwrap();
    assert!(res.test_acc > 0.7, "60-step dithered acc only {}", res.test_acc);
    assert!(res.history.mean_sparsity() > 0.7);
    assert!(res.history.max_bits() <= 8);
    // loss decreased
    let first = res.history.steps.first().unwrap().loss;
    let last = res.history.steps.last().unwrap().loss;
    assert!(last < first * 0.5, "loss {first} -> {last}");
}

#[test]
fn dithered_matches_baseline_accuracy_at_smoke_scale() {
    let engine = Engine::load(artifacts()).unwrap();
    let ds = data::build("digits", 1024, 512, 4);
    let base = train(&engine, &ds, &TrainConfig::quick("lenet300100", "baseline", 0.0, 60)).unwrap();
    let dith = train(&engine, &ds, &TrainConfig::quick("lenet300100", "dithered", 2.0, 60)).unwrap();
    assert!(
        (base.test_acc - dith.test_acc).abs() < 0.08,
        "acc gap too large: baseline {} vs dithered {}",
        base.test_acc,
        dith.test_acc
    );
    assert!(dith.history.mean_sparsity() > base.history.mean_sparsity() + 0.2);
}

#[test]
fn int8_methods_train() {
    let engine = Engine::load(artifacts()).unwrap();
    let ds = data::build("digits", 1024, 512, 5);
    for method in ["int8", "int8_dithered"] {
        let res = train(&engine, &ds, &TrainConfig::quick("mlp500", method, 2.0, 60)).unwrap();
        assert!(res.test_acc > 0.6, "{method} acc {}", res.test_acc);
    }
}

#[test]
fn distributed_two_nodes_learns_and_compresses() {
    let ds = data::build("digits", 512, 512, 6);
    let cfg = DistConfig {
        artifacts_dir: artifacts(),
        model: "mlp500".into(),
        method: "dithered".into(),
        s: 3.0,
        nodes: 2,
        rounds: 80,
        // batch-1 gradients are noisy: keep the smoke-test lr gentle
        opt: SgdConfig { lr: LrSchedule::constant(0.02), momentum: 0.9, weight_decay: 5e-4 },
        seed: 9,
        verbose: false,
    };
    let res = run_distributed(&ds, &cfg).unwrap();
    // 80 batch-1 rounds: just check learning signal + claims machinery
    assert!(res.mean_sparsity > 0.8, "sparsity {}", res.mean_sparsity);
    assert!(res.max_bits <= 8);
    assert!(res.comm.up_savings() > 2.0, "comm savings {}", res.comm.up_savings());
    assert_eq!(res.comm.rounds, 80);
    let first = res.history.steps[..20].iter().map(|r| r.loss).sum::<f32>() / 20.0;
    let last = res.history.steps[60..].iter().map(|r| r.loss).sum::<f32>() / 20.0;
    assert!(last < first, "distributed loss not decreasing: {first} -> {last}");
}

#[test]
fn distributed_noise_averaging_more_nodes_not_worse() {
    // Fig. 5 mechanism at smoke scale: same total examples, more nodes +
    // stronger dither should not collapse accuracy.
    let ds = data::build("digits", 512, 512, 7);
    let run_n = |nodes: usize, s: f32, rounds: usize| {
        let cfg = DistConfig {
            artifacts_dir: artifacts(),
            model: "lenet300100".into(),
            method: "dithered".into(),
            s,
            nodes,
            rounds,
            opt: SgdConfig { lr: LrSchedule::constant(0.05), momentum: 0.9, weight_decay: 5e-4 },
            seed: 11,
            verbose: false,
        };
        run_distributed(&ds, &cfg).unwrap()
    };
    let one = run_n(1, 2.0, 60);
    let four = run_n(4, 4.0, 60);
    // 4 nodes see 4x the examples per round; with stronger dither the
    // averaged update must stay usable
    assert!(four.test_acc >= one.test_acc - 0.1,
        "averaging failed: N=1 {} vs N=4 {}", one.test_acc, four.test_acc);
    assert!(four.mean_sparsity > one.mean_sparsity, "s scaling did not raise sparsity");
}
