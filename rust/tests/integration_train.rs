//! Integration: full training runs through the real stack on the
//! native backend — the convergence claims of the paper at smoke
//! scale, plus the distributed coordinator end to end.

use ditherprop::coordinator::{run_distributed, DistConfig};
use ditherprop::data;
use ditherprop::optim::{LrSchedule, SgdConfig};
use ditherprop::runtime::Engine;
use ditherprop::train::{train, TrainConfig};

/// A directory that never hosts AOT artifacts, so `Engine::load` always
/// serves the built-in native zoo here — even in an `xla`-featured tree
/// with generated artifacts (those are covered by integration_xla.rs).
/// The same string feeds the distributed workers.
fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/native-zoo").to_string()
}

#[test]
fn dithered_training_learns_and_stays_sparse() {
    let engine = Engine::load(artifacts()).unwrap();
    let ds = data::build("digits", 1024, 512, 3);
    let cfg = TrainConfig::quick("mlp128", "dithered", 2.0, 80);
    let res = train(&engine, &ds, &cfg).unwrap();
    assert!(res.test_acc > 0.6, "80-step dithered acc only {}", res.test_acc);
    assert!(res.history.mean_sparsity() > 0.6, "sparsity {}", res.history.mean_sparsity());
    assert!(res.history.max_bits() <= 8);
    // loss decreased
    let first = res.history.steps.first().unwrap().loss;
    let last = res.history.steps.last().unwrap().loss;
    assert!(last < first * 0.6, "loss {first} -> {last}");
}

#[test]
fn all_paper_methods_train_end_to_end() {
    // The acceptance sweep: baseline / dithered / meprop through the
    // full train loop, dithered reporting nonzero per-layer sparsity.
    let engine = Engine::load(artifacts()).unwrap();
    let ds = data::build("digits", 512, 512, 4);
    for method in ["baseline", "dithered", "meprop_k10", "int8", "int8_dithered", "detq"] {
        let cfg = TrainConfig::quick("mlp128", method, 2.0, 25);
        let res = train(&engine, &ds, &cfg)
            .unwrap_or_else(|e| panic!("{method} failed: {e:?}"));
        assert!(res.test_acc > 0.15, "{method} acc {}", res.test_acc);
        if method == "dithered" {
            let rec = res.history.steps.last().unwrap();
            assert!(
                rec.layer_sparsity.iter().all(|&s| s > 0.0),
                "dithered per-layer sparsity has zeros: {:?}",
                rec.layer_sparsity
            );
        }
    }
}

#[test]
fn dithered_matches_baseline_accuracy_at_smoke_scale() {
    let engine = Engine::load(artifacts()).unwrap();
    let ds = data::build("digits", 1024, 512, 4);
    let base =
        train(&engine, &ds, &TrainConfig::quick("lenet300100", "baseline", 0.0, 60)).unwrap();
    let dith =
        train(&engine, &ds, &TrainConfig::quick("lenet300100", "dithered", 2.0, 60)).unwrap();
    assert!(
        (base.test_acc - dith.test_acc).abs() < 0.15,
        "acc gap too large: baseline {} vs dithered {}",
        base.test_acc,
        dith.test_acc
    );
    assert!(dith.history.mean_sparsity() > base.history.mean_sparsity() + 0.1);
}

#[test]
fn distributed_two_nodes_learns_and_compresses() {
    let ds = data::build("digits", 512, 512, 6);
    let cfg = DistConfig {
        artifacts_dir: artifacts(),
        model: "mlp128".into(),
        method: "dithered".into(),
        s: 3.0,
        nodes: 2,
        rounds: 120,
        // batch-1 gradients are noisy: keep the smoke-test lr gentle
        opt: SgdConfig { lr: LrSchedule::constant(0.02), momentum: 0.9, weight_decay: 5e-4 },
        seed: 9,
        verbose: false,
        data: None,
        round_timeout: DistConfig::DEFAULT_ROUND_TIMEOUT,
        async_cfg: None,
    };
    let res = run_distributed(&ds, &cfg).unwrap();
    assert!(res.mean_sparsity > 0.7, "sparsity {}", res.mean_sparsity);
    assert!(res.max_bits <= 8);
    assert!(res.comm.up_savings() > 1.5, "comm savings {}", res.comm.up_savings());
    assert_eq!(res.comm.rounds, 120);
    let first = res.history.steps[..30].iter().map(|r| r.loss).sum::<f32>() / 30.0;
    let last = res.history.steps[90..].iter().map(|r| r.loss).sum::<f32>() / 30.0;
    assert!(last < first, "distributed loss not decreasing: {first} -> {last}");
}

#[test]
fn distributed_runs_every_method() {
    let ds = data::build("digits", 256, 512, 8);
    for method in ["baseline", "dithered", "meprop_k10"] {
        let cfg = DistConfig {
            artifacts_dir: artifacts(),
            model: "mlp128".into(),
            method: method.into(),
            s: 3.0,
            nodes: 2,
            rounds: 20,
            opt: SgdConfig { lr: LrSchedule::constant(0.02), momentum: 0.9, weight_decay: 5e-4 },
            seed: 13,
            verbose: false,
            data: None,
            round_timeout: DistConfig::DEFAULT_ROUND_TIMEOUT,
            async_cfg: None,
        };
        let res = run_distributed(&ds, &cfg)
            .unwrap_or_else(|e| panic!("distributed {method} failed: {e:?}"));
        assert_eq!(res.history.steps.len(), 20);
        if method == "dithered" {
            assert!(res.mean_sparsity > 0.5, "{method} sparsity {}", res.mean_sparsity);
        }
    }
}

#[test]
fn distributed_noise_averaging_more_nodes_not_worse() {
    // Fig. 5 mechanism at smoke scale: more nodes + stronger dither
    // must not collapse accuracy, and the s scaling must raise per-node
    // sparsity.
    let ds = data::build("digits", 512, 512, 7);
    let run_n = |nodes: usize, s: f32, rounds: usize| {
        let cfg = DistConfig {
            artifacts_dir: artifacts(),
            model: "lenet300100".into(),
            method: "dithered".into(),
            s,
            nodes,
            rounds,
            opt: SgdConfig { lr: LrSchedule::constant(0.05), momentum: 0.9, weight_decay: 5e-4 },
            seed: 11,
            verbose: false,
            data: None,
            round_timeout: DistConfig::DEFAULT_ROUND_TIMEOUT,
            async_cfg: None,
        };
        run_distributed(&ds, &cfg).unwrap()
    };
    let one = run_n(1, 2.0, 60);
    let four = run_n(4, 4.0, 60);
    // 4 nodes see 4x the examples per round; with stronger dither the
    // averaged update must stay usable
    assert!(
        four.test_acc >= one.test_acc - 0.15,
        "averaging failed: N=1 {} vs N=4 {}",
        one.test_acc,
        four.test_acc
    );
    assert!(four.mean_sparsity > one.mean_sparsity, "s scaling did not raise sparsity");
}
