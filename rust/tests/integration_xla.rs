//! Integration tests against the PJRT/XLA backend and the real AOT
//! artifacts.  Compiled only with `--features xla` (which requires the
//! vendored `xla` binding crate) and require `python3
//! python/compile/aot.py --out rust/artifacts` to have run.
#![cfg(feature = "xla")]

use ditherprop::data;
use ditherprop::runtime::Engine;

fn engine() -> Engine {
    Engine::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("artifacts missing — run `python3 python/compile/aot.py`")
}

#[test]
fn manifest_lists_the_conv_models_too() {
    let e = engine();
    assert!(e.capabilities().conv, "artifacts present but conv backend not selected");
    for m in ["lenet300100", "lenet5", "mlp500", "minivgg"] {
        let entry = e.manifest.model(m).unwrap();
        assert!(entry.n_params() >= 6);
        assert!(entry.total_weights() > 10_000);
    }
}

#[test]
fn grad_step_matches_contract_through_backend_dispatch() {
    let e = engine();
    let sess = e.training_session("mlp500", "dithered", 64).unwrap();
    let params = e.init_params("mlp500", 0).unwrap();
    let ds = data::build("digits", 256, 64, 5);
    let mut it = data::BatchIter::new(&ds.train, 64, 1);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 9, 2.0).unwrap();
    assert_eq!(out.grads.len(), 6);
    assert_eq!(out.sparsity.len(), 3);
    assert_eq!(out.max_level.len(), 3);
    assert!(out.mean_sparsity() > 0.5, "dithered sparsity too low: {:?}", out.sparsity);
    assert!(out.max_bitwidth() <= 8);
}

#[test]
fn conv_model_trains_a_step() {
    let e = engine();
    let sess = e.training_session("minivgg", "dithered", 64).unwrap();
    let params = e.init_params("minivgg", 1).unwrap();
    let ds = data::build("textures", 128, 64, 6);
    let mut it = data::BatchIter::new(&ds.train, 64, 2);
    it.next_batch(&ds.train);
    let out = sess.grad(&params, &it.x, &it.y, 3, 2.0).unwrap();
    assert_eq!(out.grads.len(), 12);
    assert!(out.loss.is_finite());
}
