//! Native-backend correctness against host references:
//!
//! * gradient-check the baseline backward pass against central finite
//!   differences of the eval loss, on a tiny injected topology;
//! * property-test that dithered gradients land on the Delta grid
//!   (recovered from the reported `max_level`) with sparsity >= the
//!   baseline's, using batch-1 bias gradients (which *are* the layer's
//!   compressed delta_z row).

use ditherprop::quant::grid_stats;
use ditherprop::runtime::backend::native::NativeBackend;
use ditherprop::runtime::{Backend, Engine, SessionSpec};
use ditherprop::tensor::Tensor;
use ditherprop::util::prop::{check, Gen};
use ditherprop::util::rng::Rng;
use std::path::Path;

const TINY_REGISTRY: &str = r#"{
  "version": 1,
  "train_batch": 8,
  "worker_batch": 1,
  "eval_batch": 8,
  "models": {
    "tiny": {
      "dims": [8, 6, 4],
      "dataset": "digits",
      "eval_batch": 8,
      "methods": ["baseline", "dithered", "meprop_k3"]
    }
  }
}"#;

fn tiny_backend() -> NativeBackend {
    NativeBackend::from_json(TINY_REGISTRY, Path::new(".")).unwrap()
}

fn random_batch(batch: usize, dim: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..batch * dim).map(|_| rng.normal() * 0.7).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(classes) as i32).collect();
    (x, y)
}

#[test]
fn baseline_grads_match_finite_differences() {
    let backend = tiny_backend();
    let spec = SessionSpec { model: "tiny".into(), method: "baseline".into(), batch: 8 };
    let params = backend.init_params("tiny", 3).unwrap();
    let (x, y) = random_batch(8, 8, 4, 17);

    let analytic = backend.grad_step(&spec, &params, &x, &y, 0, 0.0).unwrap();
    let loss_at = |params: &[Tensor]| -> f32 {
        backend.eval_step(&spec, params, &x, &y).unwrap().loss
    };
    assert!((analytic.loss - loss_at(&params)).abs() < 1e-6);

    let eps = 2e-3f32;
    let mut checked = 0usize;
    let mut outliers = 0usize;
    let mut dot = 0.0f64;
    let mut n_a = 0.0f64;
    let mut n_f = 0.0f64;
    for pi in 0..params.len() {
        for ci in 0..params[pi].len() {
            let mut plus = params.clone();
            plus[pi].data_mut()[ci] += eps;
            let mut minus = params.clone();
            minus[pi].data_mut()[ci] -= eps;
            let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            let g = analytic.grads[pi].data()[ci];
            // a ReLU kink inside the eps window can perturb a couple of
            // coordinates; everything else must agree tightly
            if (fd - g).abs() > 5e-3 {
                outliers += 1;
            }
            dot += fd as f64 * g as f64;
            n_a += (g as f64) * (g as f64);
            n_f += (fd as f64) * (fd as f64);
            checked += 1;
        }
    }
    // tiny topology: 8*6+6+6*4+4 = 82 coordinates, all checked
    assert_eq!(checked, 82);
    assert!(outliers <= 2, "finite-difference mismatch on {outliers}/82 coordinates");
    let cosine = dot / (n_a.sqrt() * n_f.sqrt()).max(1e-12);
    assert!(cosine > 0.995, "gradient direction off: cosine {cosine}");
}

#[test]
fn meprop_grads_match_finite_differences_of_nothing_extra() {
    // meProp zeroes delta_z entries; the surviving computation must
    // still be a correct chain rule: at k >= row width it IS baseline.
    let backend = tiny_backend();
    let spec_base = SessionSpec { model: "tiny".into(), method: "baseline".into(), batch: 4 };
    let spec_k = SessionSpec { model: "tiny".into(), method: "meprop_k3".into(), batch: 4 };
    let params = backend.init_params("tiny", 5).unwrap();
    let (x, y) = random_batch(4, 8, 4, 23);
    let gb = backend.grad_step(&spec_base, &params, &x, &y, 0, 0.0).unwrap();
    let gk = backend.grad_step(&spec_k, &params, &x, &y, 0, 0.0).unwrap();
    // k=3 on widths 6 and 4: strictly sparser or equal bias grads
    for (b, k) in gb.grads.iter().zip(gk.grads.iter()) {
        assert_eq!(b.shape(), k.shape());
    }
    assert!(gk.mean_sparsity() >= gb.mean_sparsity());
}

#[test]
fn dithered_batch1_bias_grads_live_on_the_delta_grid() {
    // At batch 1 the bias gradient of layer i IS the compressed
    // delta_z row, so the public GradOut exposes the quantized tensor
    // directly: recover Delta from max_level and verify the grid.
    let engine = Engine::native().unwrap();
    let sess = engine.training_session("mlp128", "dithered", 1).unwrap();
    let base = engine.training_session("mlp128", "baseline", 1).unwrap();
    let params = engine.init_params("mlp128", 2).unwrap();

    check("dithered bias grads on-grid, sparsity >= baseline", 25, |g: &mut Gen| {
        let seed = g.u32();
        let s = g.f32_in(1.0, 6.0);
        let (x, y) = random_batch(1, 784, 10, seed as u64 ^ 0xD17);
        let d = sess.grad(&params, &x, &y, seed, s).unwrap();
        let b = base.grad(&params, &x, &y, seed, 0.0).unwrap();
        // bias params are at odd indices: fc1_b = 1, fc2_b = 3
        for (layer, bias_idx) in [(0usize, 1usize), (1, 3)] {
            let qrow = d.grads[bias_idx].data();
            let max_level = d.max_level[layer];
            let brow = b.grads[bias_idx].data();
            let base_sparsity = grid_stats_zero_fraction(brow);
            if max_level == 0.0 {
                // everything quantized away: trivially on-grid, max sparsity
                if qrow.iter().any(|&v| v != 0.0) {
                    return false;
                }
                continue;
            }
            let max_abs = qrow.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let delta = max_abs / max_level;
            for &v in qrow {
                let level = v / delta;
                if (level - level.round()).abs() > 1e-3 {
                    return false;
                }
            }
            let st = grid_stats(qrow, delta);
            // reported stats must match a host recomputation
            if (st.sparsity - d.sparsity[layer]).abs() > 1e-6 {
                return false;
            }
            if st.sparsity + 1e-6 < base_sparsity {
                return false;
            }
        }
        true
    });
}

fn grid_stats_zero_fraction(values: &[f32]) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|&&v| v == 0.0).count() as f32 / values.len() as f32
}

#[test]
fn custom_registry_flows_through_engine() {
    let engine = Engine::from_backend(Box::new(tiny_backend()));
    assert_eq!(engine.manifest.train_batch, 8);
    let entry = engine.manifest.model("tiny").unwrap();
    assert_eq!(entry.total_weights(), 82);
    let sess = engine.training_session("tiny", "dithered", 8).unwrap();
    let params = engine.init_params("tiny", 0).unwrap();
    let (x, y) = random_batch(8, 8, 4, 31);
    let out = sess.grad(&params, &x, &y, 5, 2.0).unwrap();
    assert_eq!(out.sparsity.len(), 2);
    let ev = sess.eval(&params, &x, &y).unwrap();
    assert!(ev.loss > 0.0);
}
