//! Native-backend correctness against host references:
//!
//! * gradient-check the backward pass against central finite
//!   differences of the train-mode loss, on tiny injected topologies —
//!   an MLP, a conv→pool→dense graph, and a conv→bn→residual graph —
//!   for `baseline` and for `dithered` in its Δ→0 limit (s = 0), where
//!   it must coincide with baseline exactly (BN running-stat slots
//!   carry replacement values, not gradients, and are skipped);
//! * property-test that dithered gradients land on the Delta grid with
//!   sparsity >= the baseline's and monotone in the dither scale —
//!   via batch-1 bias gradients for dense layers (which *are* the
//!   layer's compressed delta_z row) and via the executor's delta_z
//!   trace for conv feature maps (whose bias gradients are position
//!   sums, not the maps themselves), including a conv whose backward
//!   delta arrives re-densified through a BatchNorm + skip junction;
//! * property-test the blocked and threaded GEMM kernels against the
//!   scalar reference oracle across a randomized
//!   (din, dout, batch, sparsity, nthreads) grid, to the bit;
//! * regression-test that full lenet5 / resnet8 / vgg8bn dithered
//!   training runs are bit-identical across `DITHERPROP_THREADS`
//!   settings, the pooled/scoped spawn modes, and the fused/two-pass
//!   quantize emission paths.

use ditherprop::data;
use ditherprop::kernels;
use ditherprop::optim::{Sgd, SgdConfig};
use ditherprop::quant::grid_stats;
use ditherprop::runtime::backend::native::{graph, Method, NativeBackend};
use ditherprop::runtime::{Backend, Engine, SessionSpec};
use ditherprop::sparse::CsrVec;
use ditherprop::tensor::Tensor;
use ditherprop::util::prop::{check, Gen};
use ditherprop::util::rng::Rng;
use std::path::Path;

const TINY_REGISTRY: &str = r#"{
  "version": 1,
  "train_batch": 8,
  "worker_batch": 1,
  "eval_batch": 8,
  "models": {
    "tiny": {
      "dims": [8, 6, 4],
      "dataset": "digits",
      "eval_batch": 8,
      "methods": ["baseline", "dithered", "meprop_k3"]
    },
    "tinyconv": {
      "input": [6, 6, 1],
      "layers": [
        {"type": "conv", "out": 3, "k": 3, "pad": 1},
        {"type": "pool", "k": 2},
        {"type": "flatten"},
        {"type": "dense", "out": 4}
      ],
      "dataset": "digits",
      "eval_batch": 4,
      "lr": 0.05,
      "methods": ["baseline", "dithered", "meprop_k3"]
    },
    "tinyres": {
      "input": [6, 6, 1],
      "layers": [
        {"type": "conv", "out": 2, "k": 3, "pad": 1},
        {"type": "batchnorm"},
        {"type": "residual", "layers": [
          {"type": "conv", "out": 2, "k": 3, "pad": 1},
          {"type": "batchnorm"}
        ]},
        {"type": "pool", "k": 2},
        {"type": "flatten"},
        {"type": "dense", "out": 4}
      ],
      "dataset": "digits",
      "eval_batch": 4,
      "lr": 0.05,
      "methods": ["baseline", "dithered", "meprop_k3"]
    }
  }
}"#;

fn tiny_backend() -> NativeBackend {
    NativeBackend::from_json(TINY_REGISTRY, Path::new(".")).unwrap()
}

fn random_batch(batch: usize, dim: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..batch * dim).map(|_| rng.normal() * 0.7).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(classes) as i32).collect();
    (x, y)
}

/// Central finite-difference check of `method`'s gradients against the
/// *train-mode* loss (the objective `grad_step` differentiates — for
/// BN models the eval loss normalizes with running statistics and is a
/// different function of the parameters), over every **trainable**
/// parameter coordinate of `model`; BN running-stat slots carry
/// replacement values, not gradients, and are skipped. ReLU kinks and
/// pool-argmax switches inside the eps window can perturb a couple of
/// coordinates; everything else must agree within `1e-3 * max(1, |g|)`
/// and the overall gradient direction must be essentially exact.
fn finite_difference_check(
    backend: &NativeBackend,
    model: &str,
    method: &str,
    s: f32,
    batch: usize,
    data_seed: u64,
    max_outliers: usize,
) {
    let spec = SessionSpec { model: model.into(), method: method.into(), batch };
    let params = backend.init_params(model, 3).unwrap();
    let mspec = backend.model_spec(model).unwrap();
    let trainable: Vec<bool> =
        mspec.plan().unwrap().params.iter().map(|p| p.kind.trainable()).collect();
    let entry = backend.manifest().models.get(model).unwrap().clone();
    let dim: usize = entry.input_shape.iter().product();
    let (x, y) = random_batch(batch, dim, entry.num_classes, data_seed);

    let analytic = backend.grad_step(&spec, &params, &x, &y, 0, s).unwrap();
    let loss_at = |params: &[Tensor]| -> f32 {
        graph::train_loss(mspec, params, &x, &y).unwrap()
    };
    assert!((analytic.loss - loss_at(&params)).abs() < 1e-6);

    let eps = 2e-3f32;
    let mut checked = 0usize;
    let mut outliers = 0usize;
    let mut dot = 0.0f64;
    let mut n_a = 0.0f64;
    let mut n_f = 0.0f64;
    for pi in 0..params.len() {
        if !trainable[pi] {
            continue;
        }
        for ci in 0..params[pi].len() {
            let mut plus = params.clone();
            plus[pi].data_mut()[ci] += eps;
            let mut minus = params.clone();
            minus[pi].data_mut()[ci] -= eps;
            let fd = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            let g = analytic.grads[pi].data()[ci];
            if (fd - g).abs() > 1e-3 * g.abs().max(1.0) {
                outliers += 1;
            }
            dot += fd as f64 * g as f64;
            n_a += (g as f64) * (g as f64);
            n_f += (fd as f64) * (fd as f64);
            checked += 1;
        }
    }
    let total: usize = params
        .iter()
        .zip(trainable.iter())
        .filter(|(_, &t)| t)
        .map(|(p, _)| p.len())
        .sum();
    assert_eq!(checked, total);
    assert!(
        outliers <= max_outliers,
        "{model}/{method}: finite-difference mismatch on {outliers}/{total} coordinates"
    );
    let cosine = dot / (n_a.sqrt() * n_f.sqrt()).max(1e-12);
    assert!(cosine > 0.999, "{model}/{method}: gradient direction off, cosine {cosine}");
}

#[test]
fn baseline_grads_match_finite_differences() {
    // tiny MLP: 8*6+6+6*4+4 = 82 coordinates, all checked
    finite_difference_check(&tiny_backend(), "tiny", "baseline", 0.0, 8, 17, 4);
}

#[test]
fn conv_grads_match_finite_differences() {
    // conv(3,k3,p1) -> pool(2) -> flatten(27) -> dense(4):
    // 3*3*1*3 + 3 + 27*4 + 4 = 142 coordinates, all checked.
    finite_difference_check(&tiny_backend(), "tinyconv", "baseline", 0.0, 4, 29, 6);
}

#[test]
fn conv_dithered_at_delta_zero_matches_finite_differences() {
    // s = 0 is the Δ→0 limit: the dithered path must be the exact
    // baseline chain rule, FD-verified on the conv topology too.
    finite_difference_check(&tiny_backend(), "tinyconv", "dithered", 0.0, 4, 31, 6);
}

#[test]
fn batchnorm_residual_grads_match_finite_differences() {
    // conv -> bn -> residual[conv -> bn] -> pool -> flatten -> dense:
    // the BN backward must carry the full chain rule through the batch
    // statistics (FD against the train-mode loss), and the skip
    // junction must merge both branch deltas. 142 trainable
    // coordinates checked (the 8 running-stat slots are skipped).
    finite_difference_check(&tiny_backend(), "tinyres", "baseline", 0.0, 4, 53, 8);
}

#[test]
fn batchnorm_residual_dithered_at_delta_zero_matches_finite_differences() {
    finite_difference_check(&tiny_backend(), "tinyres", "dithered", 0.0, 4, 59, 8);
}

#[test]
fn batchnorm_residual_dithered_s0_equals_baseline_bitwise() {
    let backend = tiny_backend();
    let base = SessionSpec { model: "tinyres".into(), method: "baseline".into(), batch: 4 };
    let dith = SessionSpec { model: "tinyres".into(), method: "dithered".into(), batch: 4 };
    let params = backend.init_params("tinyres", 9).unwrap();
    let (x, y) = random_batch(4, 36, 4, 47);
    let b = backend.grad_step(&base, &params, &x, &y, 7, 0.0).unwrap();
    let d = backend.grad_step(&dith, &params, &x, &y, 7, 0.0).unwrap();
    for (gb, gd) in b.grads.iter().zip(d.grads.iter()) {
        assert_eq!(gb.data(), gd.data());
    }
}

#[test]
fn conv_dithered_s0_equals_baseline_bitwise() {
    let backend = tiny_backend();
    let base = SessionSpec { model: "tinyconv".into(), method: "baseline".into(), batch: 4 };
    let dith = SessionSpec { model: "tinyconv".into(), method: "dithered".into(), batch: 4 };
    let params = backend.init_params("tinyconv", 9).unwrap();
    let (x, y) = random_batch(4, 36, 4, 43);
    let b = backend.grad_step(&base, &params, &x, &y, 7, 0.0).unwrap();
    let d = backend.grad_step(&dith, &params, &x, &y, 7, 0.0).unwrap();
    for (gb, gd) in b.grads.iter().zip(d.grads.iter()) {
        assert_eq!(gb.data(), gd.data());
    }
}

#[test]
fn meprop_grads_match_finite_differences_of_nothing_extra() {
    // meProp zeroes delta_z entries; the surviving computation must
    // still be a correct chain rule: at k >= row width it IS baseline.
    let backend = tiny_backend();
    let spec_base = SessionSpec { model: "tiny".into(), method: "baseline".into(), batch: 4 };
    let spec_k = SessionSpec { model: "tiny".into(), method: "meprop_k3".into(), batch: 4 };
    let params = backend.init_params("tiny", 5).unwrap();
    let (x, y) = random_batch(4, 8, 4, 23);
    let gb = backend.grad_step(&spec_base, &params, &x, &y, 0, 0.0).unwrap();
    let gk = backend.grad_step(&spec_k, &params, &x, &y, 0, 0.0).unwrap();
    // k=3 on widths 6 and 4: strictly sparser or equal bias grads
    for (b, k) in gb.grads.iter().zip(gk.grads.iter()) {
        assert_eq!(b.shape(), k.shape());
    }
    assert!(gk.mean_sparsity() >= gb.mean_sparsity());
}

#[test]
fn dithered_batch1_bias_grads_live_on_the_delta_grid() {
    // At batch 1 the bias gradient of a dense layer IS the compressed
    // delta_z row, so the public GradOut exposes the quantized tensor
    // directly: recover Delta from max_level and verify the grid.
    let engine = Engine::native().unwrap();
    let sess = engine.training_session("mlp128", "dithered", 1).unwrap();
    let base = engine.training_session("mlp128", "baseline", 1).unwrap();
    let params = engine.init_params("mlp128", 2).unwrap();

    check("dithered bias grads on-grid, sparsity >= baseline", 25, |g: &mut Gen| {
        let seed = g.u32();
        let s = g.f32_in(1.0, 6.0);
        let (x, y) = random_batch(1, 784, 10, seed as u64 ^ 0xD17);
        let d = sess.grad(&params, &x, &y, seed, s).unwrap();
        let b = base.grad(&params, &x, &y, seed, 0.0).unwrap();
        // bias params are at odd indices: fc1_b = 1, fc2_b = 3
        for (layer, bias_idx) in [(0usize, 1usize), (1, 3)] {
            let qrow = d.grads[bias_idx].data();
            let max_level = d.max_level[layer];
            let brow = b.grads[bias_idx].data();
            let base_sparsity = zero_fraction(brow);
            if max_level == 0.0 {
                // everything quantized away: trivially on-grid, max sparsity
                if qrow.iter().any(|&v| v != 0.0) {
                    return false;
                }
                continue;
            }
            let max_abs = qrow.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let delta = max_abs / max_level;
            for &v in qrow {
                let level = v / delta;
                if (level - level.round()).abs() > 1e-3 {
                    return false;
                }
            }
            let st = grid_stats(qrow, delta);
            // reported stats must match a host recomputation
            if (st.sparsity - d.sparsity[layer]).abs() > 1e-6 {
                return false;
            }
            if st.sparsity + 1e-6 < base_sparsity {
                return false;
            }
        }
        true
    });
}

#[test]
fn dithered_conv_delta_z_maps_live_on_the_delta_grid() {
    // Conv bias gradients are position sums of delta_z, so the grid is
    // invisible through GradOut — inspect the executor's compressed
    // delta_z trace instead: values on the recovered Δ grid, sparsity
    // >= baseline's, and sparsity monotone in the dither scale.
    let backend = tiny_backend();
    let spec = backend.model_spec("tinyconv").unwrap();
    let params = backend.init_params("tinyconv", 11).unwrap();

    check("conv delta_z on-grid, sparsity >= baseline, monotone in s", 20, |g: &mut Gen| {
        let seed = g.u32();
        let s = g.f32_in(1.0, 4.0);
        let (x, y) = random_batch(4, 36, 4, seed as u64 ^ 0xC04);
        let (base_out, base_tr) =
            graph::grad_step_traced(spec, Method::Baseline, &params, &x, &y, seed, 0.0).unwrap();
        let (out, tr) =
            graph::grad_step_traced(spec, Method::Dithered, &params, &x, &y, seed, s).unwrap();
        let (out2, _) =
            graph::grad_step_traced(spec, Method::Dithered, &params, &x, &y, seed, 2.0 * s)
                .unwrap();

        // qlayer 0 is the conv layer: batch 4 x 36 positions x 3 ch
        let (qmap, bmap) = (&tr[0], &base_tr[0]);
        if qmap.len() != 4 * 36 * 3 || bmap.len() != qmap.len() {
            return false;
        }
        let max_level = out.max_level[0];
        if max_level == 0.0 {
            // everything quantized away: trivially on-grid
            if qmap.iter().any(|&v| v != 0.0) {
                return false;
            }
        } else {
            let max_abs = qmap.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let delta = max_abs / max_level;
            for &v in qmap {
                let level = v / delta;
                if (level - level.round()).abs() > 1e-3 {
                    return false;
                }
            }
            // reported sparsity must match a host recomputation
            if (grid_stats(qmap, delta).sparsity - out.sparsity[0]).abs() > 1e-6 {
                return false;
            }
        }
        // NSD maps exact zeros to exact zeros, so conv sparsity can
        // only grow over baseline...
        if out.sparsity[0] + 1e-6 < base_out.sparsity[0] {
            return false;
        }
        if out.sparsity[0] + 1e-6 < zero_fraction(bmap) {
            return false;
        }
        // ...and a coarser grid (2s) can only zero more of the map
        // (statistically: allow sampling slack on 432 values).
        out2.sparsity[0] >= out.sparsity[0] - 0.05
    });
}

#[test]
fn dithered_bn_residual_delta_z_on_grid_and_monotone() {
    // Same Δ-grid contract through the new op set: conv1 of tinyres
    // sits BELOW a BatchNorm and a skip junction in the backward walk
    // (its incoming delta is re-densified by the BN statistics), yet
    // its freshly-compressed delta_z must land on the recovered Δ grid
    // with sparsity >= baseline's and monotone in the dither scale —
    // the per-layer re-quantization the paper's with-BN rows rely on.
    let backend = tiny_backend();
    let spec = backend.model_spec("tinyres").unwrap();
    let params = backend.init_params("tinyres", 13).unwrap();

    check("bn/residual delta_z on-grid + monotone", 15, |g: &mut Gen| {
        let seed = g.u32();
        let s = g.f32_in(1.0, 4.0);
        let (x, y) = random_batch(4, 36, 4, seed as u64 ^ 0xB17);
        let (base_out, _) =
            graph::grad_step_traced(spec, Method::Baseline, &params, &x, &y, seed, 0.0).unwrap();
        let (out, tr) =
            graph::grad_step_traced(spec, Method::Dithered, &params, &x, &y, seed, s).unwrap();
        let (out2, _) =
            graph::grad_step_traced(spec, Method::Dithered, &params, &x, &y, seed, 2.0 * s)
                .unwrap();

        // qlayers: conv1, conv2 (inside the residual), fc1
        if tr.len() != 3 || tr[0].len() != 4 * 36 * 2 {
            return false;
        }
        let qmap = &tr[0];
        let max_level = out.max_level[0];
        if max_level == 0.0 {
            if qmap.iter().any(|&v| v != 0.0) {
                return false;
            }
        } else {
            let max_abs = qmap.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let delta = max_abs / max_level;
            for &v in qmap {
                let level = v / delta;
                if (level - level.round()).abs() > 1e-3 {
                    return false;
                }
            }
            if (grid_stats(qmap, delta).sparsity - out.sparsity[0]).abs() > 1e-6 {
                return false;
            }
        }
        // the BN backward densifies the incoming delta, so baseline
        // conv1 sparsity is near zero — NSD must beat it...
        if out.sparsity[0] + 1e-6 < base_out.sparsity[0] {
            return false;
        }
        // ...and a coarser grid (2s) can only zero more of the map
        // (statistically: sampling slack on 288 values).
        out2.sparsity[0] >= out.sparsity[0] - 0.05
    });
}

fn zero_fraction(values: &[f32]) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|&&v| v == 0.0).count() as f32 / values.len() as f32
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn blocked_and_threaded_kernels_match_scalar_reference_bitwise() {
    // The kernel contract (kernels::gemm): every variant performs the
    // same f32 additions in the same order, so equality is exact — not
    // within-epsilon — across a randomized grid of layer shapes,
    // delta_z sparsity levels and thread counts.
    check("kernel equivalence (din,dout,batch,sparsity,nthreads) grid", 60, |g: &mut Gen| {
        // upper bounds chosen so the largest cases clear the kernels'
        // spawn threshold and exercise real scoped threads
        let din = g.usize_in(1..=128);
        let dout = g.usize_in(1..=64);
        let batch = g.usize_in(1..=48);
        let density = g.f32_in(0.0, 1.0);
        let nthreads = g.usize_in(1..=6);
        let mut rng = Rng::new(g.u32() as u64);
        let rows: Vec<CsrVec> = (0..batch)
            .map(|_| {
                let dense: Vec<f32> = (0..dout)
                    .map(|_| if rng.uniform() < density { rng.normal() } else { 0.0 })
                    .collect();
                CsrVec::encode(&dense)
            })
            .collect();
        let x: Vec<f32> = (0..batch * din)
            .map(|_| if rng.uniform() < 0.7 { rng.normal() } else { 0.0 })
            .collect();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal() * 0.1).collect();

        // Eq. 9 param GEMM pair
        let mut dw_ref = vec![0.0f32; din * dout];
        let mut db_ref = vec![0.0f32; dout];
        kernels::sparse_param_gemm_ref(&rows, &x, din, dout, &mut dw_ref, &mut db_ref);
        let mut dwt = vec![0.0f32; dout * din];
        let mut db_blk = vec![0.0f32; dout];
        kernels::sparse_param_gemm_blocked(&rows, &x, din, dout, &mut dwt, &mut db_blk);
        let mut dw_blk = vec![0.0f32; din * dout];
        kernels::transpose_into(&dwt, dout, din, &mut dw_blk);
        let mut dwt_thr = vec![0.0f32; dout * din];
        let mut db_thr = vec![0.0f32; dout];
        kernels::sparse_param_gemm_threaded(
            &rows,
            &x,
            din,
            dout,
            &mut dwt_thr,
            &mut db_thr,
            nthreads,
        );
        let mut dw_thr = vec![0.0f32; din * dout];
        kernels::transpose_into(&dwt_thr, dout, din, &mut dw_thr);

        // Eq. 8 input GEMM
        let wt = kernels::transpose(&w, din, dout);
        let gp_ref = kernels::sparse_input_gemm_ref(&rows, &wt, din);
        let mut gp_blk = vec![3.0f32; batch * din]; // stale data must be overwritten
        kernels::sparse_input_gemm_blocked_into(&rows, &wt, din, &mut gp_blk);
        let mut gp_thr = vec![3.0f32; batch * din];
        kernels::sparse_input_gemm_threaded_into(&rows, &wt, din, &mut gp_thr, nthreads);

        // forward affine
        let z_ref = kernels::affine_ref(&x, &w, &b, batch, din, dout);
        let mut z_blk = vec![3.0f32; batch * dout];
        kernels::affine_blocked_into(&x, &w, &b, batch, din, dout, &mut z_blk);
        let mut z_thr = vec![3.0f32; batch * dout];
        kernels::affine_threaded_into(&x, &w, &b, batch, din, dout, &mut z_thr, nthreads);

        bits_eq(&dw_ref, &dw_blk)
            && bits_eq(&dw_ref, &dw_thr)
            && bits_eq(&db_ref, &db_blk)
            && bits_eq(&db_ref, &db_thr)
            && bits_eq(&gp_ref, &gp_blk)
            && bits_eq(&gp_ref, &gp_thr)
            && bits_eq(&z_ref, &z_blk)
            && bits_eq(&z_ref, &z_thr)
    });
}

#[test]
fn dithered_training_is_bit_identical_across_thread_counts() {
    // The determinism regression the threaded executor must hold,
    // across every layer family in the zoo: full dithered runs (3 SGD
    // steps) of lenet5 (conv/pool/dense), resnet8 (BN + residual
    // junctions) and vgg8bn (deep with-BN stack) with
    // DITHERPROP_THREADS=1 vs =4 produce identical parameters — and
    // identical BN running statistics — to the bit.  The threaded runs
    // fan out over the persistent worker pool; the scoped-spawn
    // fallback and the two-pass (fuse-off) emission must land on the
    // same bits, so each model also reruns under those knobs.
    //
    // Mutating DITHERPROP_* while sibling tests run is safe here:
    // std's env accessors synchronize against each other, this is the
    // only env-mutating test in this binary, and every kernel variant
    // is bit-identical — a concurrent test observing a flipped knob
    // computes the same numbers either way.
    // Pin the variant to `auto` so the threaded driver really runs even
    // under the `DITHERPROP_KERNELS=ref` oracle test leg (which would
    // otherwise make both runs execute the identical scalar kernel);
    // EnvGuard restores the launch-time knobs when the test ends.
    use ditherprop::runtime::backend::native::methods::ENV_FUSE;
    let _kernels = kernels::EnvGuard::set(kernels::ENV_KERNELS, "auto");
    let run = |model: &str, batch: usize, threads: &str, spawn: &str, fuse: &str| -> Vec<Tensor> {
        let _t = kernels::EnvGuard::set(kernels::ENV_THREADS, threads);
        let _s = kernels::EnvGuard::set(kernels::ENV_SPAWN, spawn);
        let _f = kernels::EnvGuard::set(ENV_FUSE, fuse);
        let engine = Engine::native().unwrap();
        let sess = engine.training_session(model, "dithered", batch).unwrap();
        let mut params = engine.init_params(model, 7).unwrap();
        let ds = data::build(&sess.entry.dataset.clone(), 2 * batch, 16, 5);
        let mut it = data::BatchIter::new(&ds.train, batch, 2);
        let mut opt =
            Sgd::new(SgdConfig::paper(0.05, 100), &params).with_stat_slots(&sess.entry.params);
        for step in 0..3u32 {
            it.next_batch(&ds.train);
            let out = sess.grad(&params, &it.x, &it.y, step + 1, 2.0).unwrap();
            opt.apply(&mut params, &out.grads);
        }
        params
    };
    for (model, batch) in [("lenet5", 32), ("resnet8", 16), ("vgg8bn", 8)] {
        let p1 = run(model, batch, "1", "pooled", "on");
        let p4 = run(model, batch, "4", "pooled", "on");
        let p4_scoped = run(model, batch, "4", "scoped", "on");
        let p4_two_pass = run(model, batch, "4", "pooled", "off");
        assert_eq!(p1.len(), p4.len());
        for (pi, (a, b)) in p1.iter().zip(p4.iter()).enumerate() {
            assert!(
                bits_eq(a.data(), b.data()),
                "{model}: param {pi} diverged between DITHERPROP_THREADS=1 and =4"
            );
        }
        for (pi, (a, b)) in p4.iter().zip(p4_scoped.iter()).enumerate() {
            assert!(
                bits_eq(a.data(), b.data()),
                "{model}: param {pi} diverged between pooled and scoped spawn"
            );
        }
        for (pi, (a, b)) in p4.iter().zip(p4_two_pass.iter()).enumerate() {
            assert!(
                bits_eq(a.data(), b.data()),
                "{model}: param {pi} diverged between fused and two-pass emission"
            );
        }
    }
}

#[test]
fn custom_registry_flows_through_engine() {
    let engine = Engine::from_backend(Box::new(tiny_backend()));
    assert_eq!(engine.manifest.train_batch, 8);
    let entry = engine.manifest.model("tiny").unwrap();
    assert_eq!(entry.total_weights(), 82);
    let sess = engine.training_session("tiny", "dithered", 8).unwrap();
    let params = engine.init_params("tiny", 0).unwrap();
    let (x, y) = random_batch(8, 8, 4, 31);
    let out = sess.grad(&params, &x, &y, 5, 2.0).unwrap();
    assert_eq!(out.sparsity.len(), 2);
    let ev = sess.eval(&params, &x, &y).unwrap();
    assert!(ev.loss > 0.0);
}

#[test]
fn custom_conv_registry_flows_through_engine() {
    let engine = Engine::from_backend(Box::new(tiny_backend()));
    let entry = engine.manifest.model("tinyconv").unwrap();
    assert_eq!(entry.params[0].name, "conv1_w");
    assert_eq!(entry.params[0].shape, vec![3, 3, 1, 3]);
    assert_eq!(entry.n_qlayers, 2);
    assert_eq!(entry.lr, Some(0.05));
    assert_eq!(entry.requires, vec!["conv".to_string()]);
    let sess = engine.training_session("tinyconv", "dithered", 4).unwrap();
    let params = engine.init_params("tinyconv", 0).unwrap();
    let (x, y) = random_batch(4, 36, 4, 37);
    let out = sess.grad(&params, &x, &y, 5, 2.0).unwrap();
    assert_eq!(out.grads.len(), 4);
    assert_eq!(out.sparsity.len(), 2);
    let ev = sess.eval(&params, &x, &y).unwrap();
    assert!(ev.loss > 0.0);
}

#[test]
fn custom_bn_residual_registry_flows_through_engine() {
    // The parsed-registry path: batchnorm + residual schema entries
    // produce the full param surface (incl. stat slots), advertise
    // their feature requirements, and run a 2-step training loop whose
    // running statistics actually move off their init.
    let engine = Engine::from_backend(Box::new(tiny_backend()));
    let entry = engine.manifest.model("tinyres").unwrap().clone();
    assert_eq!(entry.requires, vec!["conv".to_string(), "batchnorm".to_string(), "residual".to_string()]);
    assert_eq!(entry.n_qlayers, 3); // conv1, conv2 (in the block), fc1
    // conv1 w/b, bn1 g/b/m/v, conv2 w/b, bn2 g/b/m/v, fc1 w/b
    assert_eq!(entry.n_params(), 14);
    assert_eq!(entry.params[2].name, "bn1_g");
    assert_eq!(entry.params[5].name, "bn1_v");
    let sess = engine.training_session("tinyres", "dithered", 4).unwrap();
    let mut params = engine.init_params("tinyres", 0).unwrap();
    // init: gamma/running-var one, beta/running-mean zero
    assert!(params[2].data().iter().all(|&v| v == 1.0));
    assert_eq!(params[3].abs_max(), 0.0);
    assert_eq!(params[4].abs_max(), 0.0);
    assert!(params[5].data().iter().all(|&v| v == 1.0));
    let mut opt = Sgd::new(SgdConfig::paper(0.05, 100), &params).with_stat_slots(&entry.params);
    let (x, y) = random_batch(4, 36, 4, 71);
    for step in 0..2u32 {
        let out = sess.grad(&params, &x, &y, step + 1, 2.0).unwrap();
        assert_eq!(out.grads.len(), 14);
        assert_eq!(out.sparsity.len(), 3);
        opt.apply(&mut params, &out.grads);
    }
    // running mean moved off zero; running var off one (EMA of batch stats)
    assert!(params[4].abs_max() > 0.0, "bn1 running mean never updated");
    assert!(
        params[5].data().iter().any(|&v| (v - 1.0).abs() > 1e-6),
        "bn1 running var never updated"
    );
    let ev = sess.eval(&params, &x, &y).unwrap();
    assert!(ev.loss.is_finite() && ev.loss > 0.0);
}
