//! Decoder-robustness property tests for the wire codec.
//!
//! The transport boundary is the one place the process parses bytes it
//! did not produce, so the contract is absolute: *any* corrupt input —
//! truncated, bit-flipped, oversized, or pure garbage — must come back
//! as `Err`, never a panic and never an attacker-sized allocation.
//! These tests drive `net::frame` and `net::proto::Msg::decode` with
//! systematically corrupted encodings of every message variant; a panic
//! anywhere in the decode path fails the test.

use ditherprop::coordinator::comm::EncodedGrads;
use ditherprop::data::DataSpec;
use ditherprop::net::frame::{
    encode_frame, parse_frame, parse_header, read_frame, HEADER_LEN, MAGIC, MAX_FRAME,
    WIRE_VERSION,
};
use ditherprop::net::{AsyncJob, Msg, Welcome, PROTO_VERSION};
use ditherprop::tensor::Tensor;
use ditherprop::util::prop::{check, Gen};
use std::io::Cursor;

/// One encoding of every message variant (and both Welcome dataset
/// arms), with enough internal structure — strings, counted vectors,
/// nested codecs — that corruption can land in any field kind.
fn sample_msgs() -> Vec<Msg> {
    let dense = Tensor::from_vec(&[2, 3], vec![0.5, 0.25, -1.25, 4.0, 3.0, -0.5]);
    let sparse = Tensor::from_vec(&[8], vec![0.0, 1.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0]);
    vec![
        Msg::Hello {
            proto: PROTO_VERSION,
            platform: "native-cpu".into(),
            features: vec!["conv".into(), "batchnorm".into(), "residual".into()],
        },
        Msg::Welcome(Welcome {
            node: 3,
            nodes: 8,
            rounds: 100,
            seed: 42,
            s: 0.5,
            model: "mlp500".into(),
            method: "dithered".into(),
            data: Some(DataSpec { kind: "digits".into(), n_train: 4096, n_test: 512, seed: 7 }),
            async_job: Some(AsyncJob { shards: 4, max_staleness: 8 }),
        }),
        Msg::Welcome(Welcome {
            node: 0,
            nodes: 1,
            rounds: 1,
            seed: 0,
            s: 0.125,
            model: "mlp500".into(),
            method: "baseline".into(),
            data: None,
            async_job: None,
        }),
        Msg::Params { round: 9, tensors: vec![vec![1.0; 16], vec![-0.5; 4], vec![]] },
        Msg::Grads {
            node: 1,
            round: 9,
            grads: EncodedGrads::encode(&[dense, sparse], 0.7, 1.0, vec![0.6, 0.9], vec![2.0, 1.0]),
        },
        Msg::Heartbeat { node: 2, round: 5 },
        Msg::Shutdown { fault: false, reason: "orderly shutdown: run complete".into() },
        Msg::Shutdown { fault: true, reason: "dropped as a straggler: no upload within 2s".into() },
        Msg::PullParams { node: 6, shard: 3 },
        Msg::ShardParams {
            shard: 3,
            version: (1 << 40) + 5,
            tensors: vec![vec![0.5, -0.5, 2.0], vec![], vec![-9.0]],
        },
        Msg::PushGrads {
            node: 6,
            shard: 3,
            version: 17,
            grads: EncodedGrads::encode(
                &[Tensor::from_vec(&[4], vec![0.0, 0.0, 1.5, 0.0])],
                0.25,
                0.0,
                vec![0.75],
                vec![1.0],
            ),
        },
        Msg::InferRequest {
            id: 77,
            model: "vgg8bn".into(),
            batch: 2,
            x: vec![0.25, -0.5, 0.75, 1.0, 0.0, -1.0],
        },
        Msg::InferReply {
            id: 77,
            classes: 3,
            preds: vec![2, 0],
            logits: vec![0.1, 0.2, 0.7, 0.6, 0.3, 0.1],
        },
        Msg::Busy { id: 78, retry_after_ms: 250 },
    ]
}

#[test]
fn every_sample_roundtrips() {
    // Sanity anchor: the corruption tests below only mean something if
    // the uncorrupted encodings decode back to the original.
    for msg in sample_msgs() {
        let payload = msg.encode_payload();
        let back = Msg::decode(msg.tag(), &payload).expect("valid encoding must decode");
        assert_eq!(back, msg);
        let frame = encode_frame(msg.tag(), &payload);
        let (tag, body) = parse_frame(&frame).expect("valid frame must parse");
        assert_eq!((tag, body), (msg.tag(), payload.as_slice()));
        let (tag, body) = read_frame(&mut Cursor::new(&frame)).expect("valid stream must read");
        assert_eq!((tag, body.as_slice()), (msg.tag(), payload.as_slice()));
    }
}

#[test]
fn every_strict_prefix_of_a_payload_fails_decode() {
    // Truncation at *every* byte offset, not a random sample: the
    // payloads are small enough to sweep exhaustively, and `Rd::done`
    // guarantees no strict prefix can masquerade as a complete message.
    for msg in sample_msgs() {
        let payload = msg.encode_payload();
        for cut in 0..payload.len() {
            let r = Msg::decode(msg.tag(), &payload[..cut]);
            assert!(
                r.is_err(),
                "tag {} truncated to {cut}/{} bytes decoded as {:?}",
                msg.tag(),
                payload.len(),
                r
            );
        }
    }
}

#[test]
fn every_strict_prefix_of_a_frame_stream_fails_read() {
    for msg in sample_msgs() {
        let frame = encode_frame(msg.tag(), &msg.encode_payload());
        for cut in 0..frame.len() {
            assert!(
                read_frame(&mut Cursor::new(&frame[..cut])).is_err(),
                "stream truncated to {cut}/{} bytes should not yield a frame",
                frame.len()
            );
        }
    }
}

#[test]
fn bit_flips_never_panic_and_often_fail_closed() {
    let msgs = sample_msgs();
    check("single bit flip never panics the decoder", 600, |g: &mut Gen| {
        let msg = &msgs[g.usize_in(0..=msgs.len() - 1)];
        let mut payload = msg.encode_payload();
        if payload.is_empty() {
            return true;
        }
        let byte = g.usize_in(0..=payload.len() - 1);
        let bit = g.usize_in(0..=7);
        payload[byte] ^= 1 << bit;
        match Msg::decode(msg.tag(), &payload) {
            // A flip in a value byte (not a length/count/discriminant)
            // legitimately decodes to a *different* message; the
            // decoded form must itself survive re-encoding.
            Ok(m) => {
                let _ = m.encode_payload();
                true
            }
            Err(_) => true,
        }
    });
}

#[test]
fn garbage_payloads_never_panic() {
    check("random bytes under any tag never panic", 400, |g: &mut Gen| {
        let n = g.usize_in(0..=256);
        let junk: Vec<u8> = (0..n).map(|_| (g.u32() & 0xFF) as u8).collect();
        let tag = (g.u32() & 0xFF) as u8;
        let r = Msg::decode(tag, &junk);
        // Unknown tags must always be rejected; known tags (1..=12 as
        // of proto v5) may decode by coincidence but must not panic
        // doing so.
        (1..=12).contains(&tag) || r.is_err()
    });
}

#[test]
fn corrupt_counts_cannot_force_oversized_allocations() {
    // A counted field whose count claims more elements than the payload
    // has bytes must fail *before* allocating: build a Params message
    // whose tensor count field is rewritten to u32::MAX.
    let msg = Msg::Params { round: 1, tensors: vec![vec![1.0; 8]] };
    let mut payload = msg.encode_payload();
    // layout: round u32 | tensor-count u32 | ...
    payload[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Msg::decode(msg.tag(), &payload).is_err());

    // Same attack one level down: the f32s element count of tensor 0.
    let mut payload = msg.encode_payload();
    payload[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Msg::decode(msg.tag(), &payload).is_err());

    // Serving messages carry counted vectors too. InferReply layout:
    // id u64 | classes u32 | preds-count u32 | ... — rewrite the preds
    // count to u32::MAX; decode must fail before allocating.
    let msg = Msg::InferReply { id: 1, classes: 2, preds: vec![0, 1], logits: vec![1.0; 4] };
    let mut payload = msg.encode_payload();
    payload[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Msg::decode(msg.tag(), &payload).is_err());

    // InferRequest layout: id u64 | model str | batch u32 | x f32s —
    // the batch field sits right after the 8-byte id + (u32 len)-
    // prefixed model string; an implausible batch must be rejected.
    let msg = Msg::InferRequest { id: 1, model: "m".into(), batch: 1, x: vec![0.5] };
    let mut payload = msg.encode_payload();
    let batch_at = 8 + 4 + 1; // id + str length prefix + "m"
    payload[batch_at..batch_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Msg::decode(msg.tag(), &payload).is_err());

    // Busy layout: id u64 | retry_after_ms u32 — a corrupt hint beyond
    // the one-hour plausibility guard must be rejected (a client would
    // otherwise sleep on attacker-chosen durations).
    let msg = Msg::Busy { id: 1, retry_after_ms: 5 };
    let mut payload = msg.encode_payload();
    payload[8..12].copy_from_slice(&3_600_001u32.to_le_bytes());
    assert!(Msg::decode(msg.tag(), &payload).is_err());
}

#[test]
fn header_validation_rejects_magic_version_and_oversize() {
    let good = encode_frame(3, &[1, 2, 3, 4]);
    let header = |f: &dyn Fn(&mut [u8; HEADER_LEN])| {
        let mut h = [0u8; HEADER_LEN];
        h.copy_from_slice(&good[..HEADER_LEN]);
        f(&mut h);
        h
    };

    assert!(parse_header(header(&|_| {})).is_ok());
    assert!(parse_header(header(&|h| h[0] ^= 0xFF)).is_err(), "bad magic[0] must fail");
    assert!(parse_header(header(&|h| h[1] ^= 0x01)).is_err(), "bad magic[1] must fail");
    assert!(
        parse_header(header(&|h| h[2] = WIRE_VERSION + 1)).is_err(),
        "future wire version must fail"
    );
    let oversize = (MAX_FRAME as u32 + 1).to_le_bytes();
    assert!(
        parse_header(header(&|h| h[4..8].copy_from_slice(&oversize))).is_err(),
        "length beyond MAX_FRAME must fail"
    );
    // tag is opaque at the frame layer: any tag byte passes the header
    assert!(parse_header(header(&|h| h[3] = 0xEE)).is_ok());
}

#[test]
fn frame_length_field_must_match_the_buffer() {
    let frame = encode_frame(5, &[9, 9, 9, 9, 9, 9, 9, 9]);
    // shorter than a header
    for cut in 0..HEADER_LEN {
        assert!(parse_frame(&frame[..cut]).is_err());
    }
    // header intact but payload short / long
    assert!(parse_frame(&frame[..frame.len() - 1]).is_err());
    let mut long = frame.clone();
    long.push(0);
    assert!(parse_frame(&long).is_err());
}

#[test]
fn header_claiming_more_than_the_stream_holds_fails_read() {
    // A valid header promising 1000 payload bytes over a stream that
    // ends immediately: read_frame must surface the truncation.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&MAGIC);
    bytes.push(WIRE_VERSION);
    bytes.push(2);
    bytes.extend_from_slice(&1000u32.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 10]); // 10 of the promised 1000
    assert!(read_frame(&mut Cursor::new(&bytes)).is_err());
}

#[test]
fn corrupted_headers_on_a_stream_fail_read() {
    check("randomly corrupted frame streams never panic", 400, |g: &mut Gen| {
        let payload: Vec<u8> = (0..g.usize_in(0..=64)).map(|_| (g.u32() & 0xFF) as u8).collect();
        let mut frame = encode_frame(4, &payload);
        let byte = g.usize_in(0..=frame.len() - 1);
        frame[byte] ^= 1 << g.usize_in(0..=7);
        // Flips in the payload still read fine (the frame layer does
        // not interpret payload bytes), a flip that *shrinks* the
        // length field legitimately reads a shorter payload (the proto
        // layer's `Rd::done` catches that), and the tag byte is opaque
        // here — but a flip in the magic or version bytes must always
        // fail, and a payload flip must never fail.
        match read_frame(&mut Cursor::new(&frame)) {
            Ok(_) => byte >= 3,
            Err(_) => byte < HEADER_LEN,
        }
    });
}
