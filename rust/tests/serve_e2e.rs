//! End-to-end serving test: a real TCP server, concurrent clients,
//! and bitwise verification of every reply.
//!
//! This is the subsystem's headline guarantee in executable form: a
//! reply that crossed the wire — possibly micro-batched together with
//! another client's request — equals a direct in-process folded
//! forward bit-for-bit (`check: true` compares predictions *and*
//! logits by bit pattern).

#![cfg(feature = "native")]

use ditherprop::serve::{run_busy_probe, run_infer, run_serve, InferCfg, QuantMode, ServeCfg};
use ditherprop::util::math::percentile;
use std::net::TcpListener;
use std::time::Duration;

fn e2e(quant: QuantMode, model: &str, steps: usize) {
    const CLIENTS: u64 = 2;
    const REQUESTS: usize = 3;
    const WARMUP: usize = 1;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let serve_cfg = ServeCfg {
        quant,
        seed: 5,
        steps,
        // Tiny flush threshold + real delay window so concurrent
        // clients actually co-batch some rounds.
        max_batch: 4,
        max_delay: Duration::from_millis(2),
        max_requests: Some(CLIENTS * (REQUESTS + WARMUP) as u64),
        ..ServeCfg::default()
    };

    std::thread::scope(|s| {
        let server = s.spawn(|| run_serve(&listener, &serve_cfg));
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let cfg = InferCfg {
                    addr: addr.clone(),
                    model: model.to_string(),
                    batch: 1 + c as usize, // distinct batch sizes co-batched
                    requests: REQUESTS,
                    warmup: WARMUP,
                    seed: 5,
                    steps,
                    quant,
                    check: true,
                    connect_timeout: Duration::from_secs(10),
                };
                s.spawn(move || run_infer(&cfg))
            })
            .collect();
        for (c, h) in clients.into_iter().enumerate() {
            let summary = h.join().expect("client thread").expect("client run");
            assert_eq!(summary.requests as usize, REQUESTS, "client {c}");
            assert_eq!(
                summary.checked as usize,
                REQUESTS + WARMUP,
                "client {c}: every reply must verify bit-identical"
            );
            assert_eq!(summary.last_preds.len(), 1 + c);
        }
        let stats = server.join().expect("server thread").expect("server run");
        assert_eq!(stats.served, CLIENTS * (REQUESTS + WARMUP) as u64);
        assert_eq!(stats.rejected, 0);
        assert!(stats.batches > 0 && stats.batches <= stats.served);
        assert_eq!(stats.latencies_ms.len() as u64, stats.served);
        assert_eq!(stats.cache_misses, 1, "one model, prepared once");
        assert!(stats.p99_ms() >= stats.p50_ms());
    });
}

#[test]
fn int8_replies_are_bit_identical_to_local_forward() {
    // Trained weights (steps > 0) exercise the deterministic
    // cross-process reconstruction; int8 exercises the quantized path.
    e2e(QuantMode::Int8, "mlp128", 6);
}

#[test]
fn fp32_replies_are_bit_identical_on_a_folded_bn_model() {
    // vgg8bn folds real BatchNorm stages before serving.
    e2e(QuantMode::Fp32, "vgg8bn", 0);
}

/// The lane executor's headline guarantee: a slow fp32 vgg8bn client
/// and a fast int8 mlp128 client share one server, and because the two
/// models run on different execution lanes the fast model's tail
/// latency stays bounded by its own work, not the slow model's — while
/// every reply from both models remains bitwise identical to a solo
/// local forward.
#[test]
fn mixed_models_do_not_head_of_line_block() {
    const MLP_REQUESTS: usize = 12;
    const VGG_REQUESTS: usize = 5;
    const WARMUP: usize = 1;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let serve_cfg = ServeCfg {
        quant: QuantMode::Int8,
        seed: 5,
        steps: 0,
        max_batch: 8,
        max_delay: Duration::from_millis(2),
        lanes: 2,
        fp32_models: vec!["vgg8bn".into()],
        max_requests: Some((MLP_REQUESTS + VGG_REQUESTS + 2 * WARMUP) as u64),
        ..ServeCfg::default()
    };

    std::thread::scope(|s| {
        let server = s.spawn(|| run_serve(&listener, &serve_cfg));
        let client = |model: &str, batch: usize, requests: usize, quant: QuantMode| InferCfg {
            addr: addr.clone(),
            model: model.to_string(),
            batch,
            requests,
            warmup: WARMUP,
            seed: 5,
            steps: 0,
            quant,
            check: true,
            connect_timeout: Duration::from_secs(10),
        };
        let vgg = s.spawn({
            let cfg = client("vgg8bn", 4, VGG_REQUESTS, QuantMode::Fp32);
            move || run_infer(&cfg)
        });
        let mlp = s.spawn({
            let cfg = client("mlp128", 1, MLP_REQUESTS, QuantMode::Int8);
            move || run_infer(&cfg)
        });

        let vgg = vgg.join().expect("vgg thread").expect("vgg client");
        let mlp = mlp.join().expect("mlp thread").expect("mlp client");
        assert_eq!(vgg.checked as usize, VGG_REQUESTS + WARMUP, "fp32 replies bitwise clean");
        assert_eq!(mlp.checked as usize, MLP_REQUESTS + WARMUP, "int8 replies bitwise clean");

        // The head-of-line bound: with per-model lanes, the fast
        // model's p99 must stay below the slow model's median forward
        // (with a floor absorbing scheduler noise on loaded CI boxes).
        // A single serial loop cannot pass this: every mlp request
        // stuck behind a vgg batch-4 forward would inherit its latency.
        let mlp_p99 = percentile(&mlp.latencies_ms, 99.0);
        let vgg_p50 = percentile(&vgg.latencies_ms, 50.0);
        assert!(
            mlp_p99 < vgg_p50.max(25.0),
            "mlp p99 {mlp_p99:.3} ms head-of-line blocked behind vgg (p50 {vgg_p50:.3} ms)"
        );

        let stats = server.join().expect("server thread").expect("server run");
        assert_eq!(stats.served, (MLP_REQUESTS + VGG_REQUESTS + 2 * WARMUP) as u64);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.busy, 0, "well under the queue cap");
        assert_eq!(stats.lanes, 2);
        assert_eq!(stats.lane_depth_max.len(), 2);
        assert_eq!(stats.cache_misses, 2, "each model prepared once, on its own lane");
    });
}

/// Overload answers a typed `Busy`, never unbounded queueing: with the
/// queue cap forced to 1, a client that pipelines all its requests at
/// once must see at least one `Busy`, and after retrying, every reply
/// is still bitwise identical to a local forward.
#[test]
fn queue_cap_overload_returns_busy_and_replies_stay_bitwise_clean() {
    const REQUESTS: usize = 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let serve_cfg = ServeCfg {
        quant: QuantMode::Int8,
        seed: 5,
        steps: 0,
        lanes: 1,
        max_queue: 1,
        max_batch: 1,
        max_delay: Duration::from_millis(5),
        max_requests: Some(REQUESTS as u64),
        ..ServeCfg::default()
    };

    std::thread::scope(|s| {
        let server = s.spawn(|| run_serve(&listener, &serve_cfg));
        let probe_cfg = InferCfg {
            addr: addr.clone(),
            model: "mlp128".into(),
            batch: 1,
            requests: REQUESTS,
            warmup: 0,
            seed: 5,
            steps: 0,
            quant: QuantMode::Int8,
            check: true,
            connect_timeout: Duration::from_secs(10),
        };
        let probe = run_busy_probe(&probe_cfg).expect("busy probe");
        assert!(probe.busy >= 1, "cap 1 with {REQUESTS} pipelined requests must reject");
        assert_eq!(probe.served as usize, REQUESTS, "every request served after retries");
        assert_eq!(probe.checked as usize, REQUESTS, "busy retries preserve bit-identity");

        let stats = server.join().expect("server thread").expect("server run");
        assert_eq!(stats.served as usize, REQUESTS);
        assert_eq!(stats.busy, probe.busy);
        assert!(stats.lane_depth_max.iter().all(|&d| d <= 1), "cap held: {stats:?}");
    });
}

#[test]
fn invalid_requests_fault_the_connection_not_the_server() {
    use ditherprop::net::{Msg, TcpTransport, Transport};

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let serve_cfg = ServeCfg {
        quant: QuantMode::Int8,
        steps: 0,
        max_requests: Some(3), // 2 rejects + 1 served
        ..ServeCfg::default()
    };

    std::thread::scope(|s| {
        let server = s.spawn(|| run_serve(&listener, &serve_cfg));

        // Unknown model: the server must reply with a faulted Shutdown.
        let mut bad = TcpTransport::connect_retry(&addr, Duration::from_secs(10)).expect("connect");
        bad.send(&Msg::InferRequest { id: 1, model: "no-such-model".into(), batch: 1, x: vec![0.0] })
            .expect("send");
        match bad.recv_deadline(Duration::from_secs(10)).expect("recv") {
            Some(Msg::Shutdown { fault, reason }) => {
                assert!(fault, "rejection must be faulted");
                assert!(reason.contains("unknown model"), "{reason}");
            }
            other => panic!("expected faulted Shutdown, got {other:?}"),
        }

        // Wrong input size for a real model: same fate.
        let mut bad2 =
            TcpTransport::connect_retry(&addr, Duration::from_secs(10)).expect("connect");
        bad2.send(&Msg::InferRequest { id: 2, model: "mlp128".into(), batch: 1, x: vec![0.5; 3] })
            .expect("send");
        match bad2.recv_deadline(Duration::from_secs(10)).expect("recv") {
            Some(Msg::Shutdown { fault, .. }) => assert!(fault),
            other => panic!("expected faulted Shutdown, got {other:?}"),
        }

        // The server survives both and still serves a valid client.
        let good = InferCfg {
            addr: addr.clone(),
            model: "mlp128".into(),
            batch: 2,
            requests: 1,
            warmup: 0,
            seed: 42,
            steps: 0,
            quant: QuantMode::Int8,
            check: true,
            connect_timeout: Duration::from_secs(10),
        };
        let summary = run_infer(&good).expect("valid client after invalid peers");
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.checked, 1);

        let stats = server.join().expect("server thread").expect("server run");
        assert_eq!(stats.served, 1);
        assert_eq!(stats.rejected, 2);
    });
}
