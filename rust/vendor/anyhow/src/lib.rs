//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this path
//! dependency implements the exact subset the workspace uses with the
//! same names and semantics:
//!
//! * [`Error`] — a message + a stack of context notes (no backtraces,
//!   no downcasting).
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on any
//!   `Result` whose error converts into [`Error`].
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — the formatting macros.
//!
//! `Display` shows the outermost context (what the operation was);
//! `Debug` shows the full cause chain, mirroring how the real anyhow
//! renders errors escaping `main`.

use std::fmt;

/// A dynamic error: root message plus innermost-last context notes.
pub struct Error {
    msg: String,
    /// Context notes, innermost (added first) to outermost (added last).
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), context: Vec::new() }
    }

    /// Wrap with a higher-level context note.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        &self.msg
    }

    /// Outermost-first chain: context notes, then the root message.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.context
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.msg.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(c) => f.write_str(c),
            None => f.write_str(&self.msg),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain: Vec<&str> = self.chain().collect();
        f.write_str(chain[0])?;
        if chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Mirrors anyhow: every std error converts into `Error`, which is why
// `Error` itself must NOT implement `std::error::Error` (coherence).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failing results.
pub trait Context<T> {
    /// Wrap the error with a fixed context note.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily built context note.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Format an [`Error`] (accepts a format string or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading the missing file")?;
        Ok(s)
    }

    #[test]
    fn context_chain_renders() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading the missing file");
        let dbg = format!("{err:?}");
        assert!(dbg.starts_with("reading the missing file"));
        assert!(dbg.contains("Caused by:"));
    }

    #[test]
    fn with_context_on_error_results() {
        let base: Result<()> = Err(anyhow!("root {}", 7));
        let err = base.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(err.to_string(), "outer 1");
        assert_eq!(err.root_cause(), "root 7");
        assert_eq!(err.chain().count(), 2);
    }

    #[test]
    fn ensure_and_bail() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert_eq!(check(12).unwrap_err().to_string(), "n too big: 12");
        assert_eq!(check(3).unwrap_err().to_string(), "three is right out");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn check(n: usize) -> Result<()> {
            ensure!(n == 0);
            Ok(())
        }
        assert!(check(1).unwrap_err().to_string().contains("n == 0"));
    }

    #[test]
    fn anyhow_accepts_displayable_expressions() {
        let msg = String::from("plain string error");
        let err = anyhow!(msg);
        assert_eq!(err.to_string(), "plain string error");
    }
}
