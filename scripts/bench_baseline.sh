#!/usr/bin/env bash
# Re-measure the bench-gate baseline on the current host: run the
# hot-path bench at a fixed iteration count, stamp the report as a
# *measured* baseline (`meta.baseline_kind = "measured"`, vs the seed's
# hand-set "floor" rows), and rewrite BENCH_kernels.json. Review the
# diff before committing — a baseline measured on a noisy host makes
# the gate either toothless (too slow) or flaky (too fast).
#
# usage: scripts/bench_baseline.sh [iters] [out.json]
set -euo pipefail

iters="${1:-30}"
out="${2:-$(dirname "$0")/../BENCH_kernels.json}"
tmp="$(mktemp /tmp/bench_baseline.XXXXXX.json)"
trap 'rm -f "$tmp"' EXIT

(cd "$(dirname "$0")/../rust" \
  && cargo bench --bench runtime_hotpath -- --iters "$iters" --json "$tmp")

jq -e '.schema == "ditherprop-bench-v1"' "$tmp" > /dev/null \
  || { echo "bench-baseline: bench did not emit a ditherprop-bench-v1 report" >&2; exit 2; }

note="measured bench-gate baseline (scripts/bench_baseline.sh, --iters $iters, quiet host);"
note="$note scripts/bench_gate.sh fails on any kernel row missing from a fresh run"
note="$note or more than 30% below these GFLOP/s."
jq --arg note "$note" \
  '.meta.baseline_kind = "measured" | .meta.note = $note' "$tmp" > "$out"

n=$(jq '[.rows[] | select(.suite == "kernel")] | length' "$out")
echo "bench-baseline: wrote $n kernel rows (baseline_kind=measured) to $out"
