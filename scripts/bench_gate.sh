#!/usr/bin/env bash
# Bench-regression gate: compare a fresh `cargo bench --bench
# runtime_hotpath -- --json` run against the committed
# BENCH_kernels.json baseline and fail on a >30% GFLOP/s regression in
# any kernel-suite row. Plain bash + jq, no new dependencies.
#
# Kernel rows are joined on the machine-stable identity
# (op, shape, p_nz, variant) — the `threads` field varies with the
# runner and is deliberately NOT part of the key. A baseline with no
# kernel rows (the seed placeholder) gates nothing and passes, with a
# note on how to populate it.
#
# usage: scripts/bench_gate.sh <fresh.json> [baseline.json] [max_drop_pct]
set -euo pipefail

fresh="${1:?usage: bench_gate.sh <fresh.json> [baseline.json] [max_drop_pct]}"
baseline="${2:-$(dirname "$0")/../BENCH_kernels.json}"
max_drop="${3:-30}"

jq -e '.schema == "ditherprop-bench-v1"' "$fresh" > /dev/null \
  || { echo "bench-gate: $fresh is not a ditherprop-bench-v1 report" >&2; exit 2; }
jq -e '.schema == "ditherprop-bench-v1"' "$baseline" > /dev/null \
  || { echo "bench-gate: $baseline is not a ditherprop-bench-v1 report" >&2; exit 2; }

n_base=$(jq '[.rows[] | select(.suite == "kernel")] | length' "$baseline")
if [ "$n_base" -eq 0 ]; then
  echo "bench-gate: baseline $baseline has no kernel rows (seed placeholder) — nothing to gate."
  echo "bench-gate: populate it with scripts/bench_baseline.sh (measured rows), or from rust/:"
  echo "  cargo bench --bench runtime_hotpath -- --json ../BENCH_kernels.json"
  exit 0
fi

# "floor" = hand-set conservative floors, "measured" = a real
# bench_baseline.sh run; a failure message means something different in
# each case, so say which kind tripped it.
kind=$(jq -r '.meta.baseline_kind // "unknown"' "$baseline")

fails=$(jq -r --slurpfile f "$fresh" --argjson drop "$max_drop" --arg kind "$kind" '
  [ .rows[]
    | select(.suite == "kernel")
    | . as $b
    | [ $f[0].rows[]
        | select(.suite == "kernel"
                 and .op == $b.op and .shape == $b.shape
                 and .p_nz == $b.p_nz and .variant == $b.variant) ][0] as $n
    | if $n == null then
        "MISSING  \($b.op) \($b.shape) p_nz=\($b.p_nz) \($b.variant): no matching row in the fresh run (baseline_kind=\($kind))"
      elif $n.gflops < $b.gflops * (1 - $drop / 100) then
        "REGRESSED \($b.op) \($b.shape) p_nz=\($b.p_nz) \($b.variant): \($n.gflops) GF/s vs \($kind) baseline \($b.gflops) GF/s"
      else
        empty
      end
  ] | .[]' "$baseline")

if [ -n "$fails" ]; then
  echo "bench-gate: kernel GFLOP/s regression(s) beyond ${max_drop}% (baseline_kind=${kind}):"
  echo "$fails"
  exit 1
fi

n_checked=$(jq '[.rows[] | select(.suite == "kernel")] | length' "$fresh")
echo "bench-gate: ${n_base} ${kind}-baseline kernel rows checked against ${n_checked} fresh rows — all within ${max_drop}%."
