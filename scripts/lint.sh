#!/usr/bin/env bash
# Run the ditherlint static-analysis pass + the fail-closed model
# manifest verifier — the same two commands CI's `lint` leg runs
# (DESIGN.md §Static-analysis). Works from the repo root or rust/.
#
# usage: scripts/lint.sh [--json]
set -euo pipefail

here="$(cd "$(dirname "$0")/.." && pwd)"
cd "$here/rust"

cargo run --release --quiet --bin ditherlint -- lint --root src "$@"
cargo run --release --quiet --bin ditherlint -- lint-manifest "$@"
