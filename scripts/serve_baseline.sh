#!/usr/bin/env bash
# Re-measure the serve-gate baseline on the current host: run the full
# bench-serve sweep (single-model cells plus the mixed-model
# head-of-line pair at 1 vs 2 lanes), stamp the report as a *measured*
# baseline (`meta.baseline_kind = "measured"`, vs the seed's hand-set
# "bound" rows), and rewrite BENCH_serving.json. Review the diff before
# committing — a baseline measured on a noisy host makes the gate
# either toothless (too slow) or flaky (too fast).
#
# usage: scripts/serve_baseline.sh [requests_per_client] [out.json]
set -euo pipefail

requests="${1:-24}"
out="${2:-$(dirname "$0")/../BENCH_serving.json}"
tmp="$(mktemp /tmp/serve_baseline.XXXXXX.json)"
trap 'rm -f "$tmp"' EXIT

(cd "$(dirname "$0")/../rust" \
  && cargo run --release -- bench-serve --model mlp128 --quant int8 \
       --requests "$requests" --json "$tmp")

jq -e '.schema == "ditherprop-bench-v1" and .bench == "serve_latency"' "$tmp" > /dev/null \
  || { echo "serve-baseline: bench-serve did not emit a serve_latency report" >&2; exit 2; }

# Sanity before stamping: the mixed-model pair must show the lane
# executor working — the 2-lane cell's p99 under fp32 background load
# at most half the 1-lane cell's. A baseline violating this was
# measured against a broken build; refuse to commit it.
jq -e '
  ([.rows[] | select(.mixed != "none" and .lanes == 1)][0]) as $one
  | ([.rows[] | select(.mixed != "none" and .lanes >= 2)][0]) as $many
  | $one != null and $many != null and $many.p99_ms * 2 <= $one.p99_ms
' "$tmp" > /dev/null \
  || { echo "serve-baseline: mixed-model p99 not >=2x better with lanes than without" >&2
       echo "serve-baseline: refusing to stamp a baseline from a non-pipelined build" >&2
       exit 1; }

note="measured serve-gate baseline (scripts/serve_baseline.sh, --requests $requests, quiet host);"
note="$note scripts/serve_gate.sh fails on any sweep cell missing from a fresh run,"
note="$note above these p50/p99 ceilings, or below the req/s floor."
jq --arg note "$note" \
  '.meta.baseline_kind = "measured" | .meta.note = $note' "$tmp" > "$out"

n=$(jq '.rows | length' "$out")
n_mixed=$(jq '[.rows[] | select(.mixed != "none")] | length' "$out")
echo "serve-baseline: wrote $n rows ($n_mixed mixed-model) (baseline_kind=measured) to $out"
