#!/usr/bin/env bash
# Serving-latency gate: compare a fresh `ditherprop bench-serve --json`
# run against the committed BENCH_serving.json baseline and fail when a
# sweep cell blows past its bounds. Plain bash + jq, no new
# dependencies.
#
# Rows join on (model, quant, batch, clients, lanes, mixed) — lanes
# defaults to 1 and mixed to "none" on either side, so pre-lane-executor
# reports still join. The baseline's p50_ms / p99_ms are latency
# *ceilings* and req_per_s a throughput *floor*, scaled by the
# tolerance factor: a fresh cell fails if its p50 or p99 exceeds
# ceiling * tol, or its req/s drops under floor / tol. The committed
# baseline is `baseline_kind: "bound"` (generous hand-set bounds, so
# the gate catches catastrophic regressions without flaking on runner
# speed); re-measure with scripts/serve_baseline.sh to tighten it. The
# gate's verdict line names the baseline kind either way.
#
# usage: scripts/serve_gate.sh <fresh.json> [baseline.json] [tolerance]
set -euo pipefail

fresh="${1:?usage: serve_gate.sh <fresh.json> [baseline.json] [tolerance]}"
baseline="${2:-$(dirname "$0")/../BENCH_serving.json}"
tol="${3:-1.0}"

jq -e '.schema == "ditherprop-bench-v1" and .bench == "serve_latency"' "$fresh" > /dev/null \
  || { echo "serve-gate: $fresh is not a serve_latency bench report" >&2; exit 2; }
jq -e '.schema == "ditherprop-bench-v1" and .bench == "serve_latency"' "$baseline" > /dev/null \
  || { echo "serve-gate: $baseline is not a serve_latency bench report" >&2; exit 2; }

n_base=$(jq '.rows | length' "$baseline")
if [ "$n_base" -eq 0 ]; then
  echo "serve-gate: baseline $baseline has no rows — nothing to gate."
  exit 0
fi

kind=$(jq -r '.meta.baseline_kind // "unknown"' "$baseline")

fails=$(jq -r --slurpfile f "$fresh" --argjson tol "$tol" --arg kind "$kind" '
  [ .rows[]
    | . as $b
    | [ $f[0].rows[]
        | select(.model == $b.model and .quant == $b.quant
                 and .batch == $b.batch and .clients == $b.clients
                 and (.lanes // 1) == ($b.lanes // 1)
                 and (.mixed // "none") == ($b.mixed // "none")) ][0] as $n
    | if $n == null then
        "MISSING  \($b.model)/\($b.quant) b\($b.batch) c\($b.clients) l\($b.lanes // 1) mixed=\($b.mixed // "none"): no matching row in the fresh run (baseline_kind=\($kind))"
      else
        [ (if $n.p50_ms > $b.p50_ms * $tol then
             "p50 \($n.p50_ms)ms > \($kind) ceiling \($b.p50_ms)ms x \($tol)" else empty end),
          (if $n.p99_ms > $b.p99_ms * $tol then
             "p99 \($n.p99_ms)ms > \($kind) ceiling \($b.p99_ms)ms x \($tol)" else empty end),
          (if $n.req_per_s < $b.req_per_s / $tol then
             "req/s \($n.req_per_s) < \($kind) floor \($b.req_per_s) / \($tol)" else empty end)
        ]
        | if length > 0 then
            "REGRESSED \($b.model)/\($b.quant) b\($b.batch) c\($b.clients) l\($b.lanes // 1) mixed=\($b.mixed // "none"): " + join("; ")
          else empty end
      end
  ] | .[]' "$baseline")

if [ -n "$fails" ]; then
  echo "serve-gate: serving latency regression(s) vs ${kind} baseline (tolerance ${tol}):"
  echo "$fails"
  exit 1
fi

echo "serve-gate: ${n_base} ${kind}-baseline cells checked — all within p50/p99 ceilings and req/s floor (tolerance ${tol})."
